//! The discrete-event simulation engine.
//!
//! Events are (time, seq, kind) in a min-heap; instances wake to run one
//! continuous-batching iteration, QLM agents actuate LSOs at wake time,
//! and the global scheduler reorders virtual queues when the RWT
//! estimator flags trouble (§3.1 lifecycle).
//!
//! §Perf: the event loop is allocation-light in steady state. Per-instance
//! state (virtual queues, agents, wake dedup, liveness) lives in dense
//! `Vec`s indexed by `InstanceId` rather than `HashMap`s; instance views
//! are built once and refreshed in place per scheduler pass; and the
//! global scheduler receives group *references* instead of a deep clone
//! of every live group. The seed implementation cloned the virtual queue
//! and agent on every wake and the entire group table on every schedule.
//!
//! On top of that, scheduling itself is *incremental*: the engine tracks
//! which groups went dirty since the last pass (arrivals, pulls,
//! evictions, drains, failures) and hands the global scheduler just that
//! delta; the scheduler patches its cached plan instead of re-solving
//! the whole table, which is what lets `--scenario scale` push 100K+
//! queued requests through the paper's Fig. 20 regime.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap, VecDeque};
use std::time::Instant as WallInstant;

use crate::backend::{
    Instance, InstanceConfig, InstanceId, ModelCatalog, ModelId, PerfModel, RunningSeq,
};
use crate::baselines::Policy;
use crate::capacity::{
    AdmissionConfig, AdmissionController, AutoscaleConfig, Autoscaler, ClassPressure,
    ScaleDecision,
};
use crate::coordinator::agent::{InstanceObservation, QlmAgent};
use crate::coordinator::lso::LsoAction;
use crate::coordinator::request::{Request, RequestState};
use crate::coordinator::request_group::{GroupId, Grouper, RequestGroup};
use crate::coordinator::rwt::{ProfileTable, RwtEstimator};
use crate::coordinator::scheduler::{
    GlobalScheduler, InstanceView, SchedDelta, SchedulerConfig, SolverKind,
};
use crate::coordinator::virtual_queue::VirtualQueue;
use crate::coordinator::GlobalQueue;
use crate::metrics::{instance_metrics, RequestRecord, RunMetrics};
use crate::sim::profiler::ThetaCache;
use crate::workload::{SloClass, Trace};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub fleet: Vec<InstanceConfig>,
    pub catalog: ModelCatalog,
    pub policy: Policy,
    pub seed: u64,
    /// δ — request-group size as a multiple of avg batch size (§8.3).
    pub delta: f64,
    /// Average batch size used for the group-size cap.
    pub avg_batch: u32,
    /// Hard stop (simulated seconds).
    pub horizon_s: f64,
    /// Min simulated gap between global-scheduler invocations.
    pub sched_interval_s: f64,
    /// Injected instance failures (§4 Fault Tolerance): at simulated
    /// time `t`, the instance is lost — its running batch and parked KV
    /// vanish, and every affected request reverts to Waiting in the
    /// global queue. Drives the `failover` CLI scenario.
    pub failures: Vec<(f64, InstanceId)>,
    /// Allow the global scheduler's incremental delta path (on by
    /// default). Off forces a full re-solve every pass — the Fig. 20
    /// overhead baseline and the `sched_incremental` bench comparator.
    pub sched_incremental: bool,
    /// Runtime autoscaling (capacity subsystem): provision instances
    /// under sustained predicted violations, drain them when calm.
    /// `fleet` is the starting fleet; the autoscaler grows/shrinks it
    /// between `min_instances` and `max_instances`. Only meaningful for
    /// group-based policies (QLM / SHEPHERD).
    pub autoscale: Option<AutoscaleConfig>,
    /// Submit-time admission control (shed batch classes when even the
    /// maximal fleet cannot meet their SLO). Disabled by default.
    pub admission: AdmissionConfig,
}

impl SimConfig {
    pub fn new(fleet: Vec<InstanceConfig>, catalog: ModelCatalog, policy: Policy) -> Self {
        SimConfig {
            fleet,
            catalog,
            policy,
            seed: 0,
            delta: 4.0,
            avg_batch: 64,
            horizon_s: 7200.0,
            sched_interval_s: 0.25,
            failures: Vec::new(),
            sched_incremental: true,
            autoscale: None,
            admission: AdmissionConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival(usize),
    Wake(InstanceId),
    Fail(InstanceId),
    /// A provisioned instance finishes its cold start and joins the
    /// fleet (autoscaler scale-up).
    Provision(InstanceId),
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

/// Waiting (or evicted) members of a group, FCFS.
fn waiting_members(
    groups: &HashMap<GroupId, RequestGroup>,
    queue: &GlobalQueue,
    gid: GroupId,
) -> Vec<u64> {
    let Some(g) = groups.get(&gid) else {
        return Vec::new();
    };
    g.members
        .iter()
        .copied()
        .filter(|id| {
            queue
                .get(*id)
                .map(|r| matches!(r.state, RequestState::Waiting | RequestState::Evicted))
                .unwrap_or(false)
        })
        .collect()
}

/// The simulator.
pub struct Simulation {
    cfg: SimConfig,
    now: f64,
    seq: u64,
    events: BinaryHeap<Reverse<Event>>,
    instances: Vec<Instance>,
    /// Dense per-instance state, indexed by `InstanceId.0`.
    vqs: Vec<VirtualQueue>,
    agents: Vec<QlmAgent>,
    alive: Vec<bool>,
    queue: GlobalQueue,
    groups: HashMap<GroupId, RequestGroup>,
    group_of: HashMap<u64, GroupId>,
    grouper: Grouper,
    scheduler: GlobalScheduler,
    /// Static model pinning for no-swap policies (vLLM baseline).
    pinned_model: HashMap<InstanceId, ModelId>,
    needs_schedule: bool,
    last_schedule: f64,
    scheduler_wall_s: f64,
    scheduler_invocations: u64,
    /// Per-instance wake deduplication: at most one pending Wake per
    /// instance (avoids event-storm blowup). An earlier wake supersedes
    /// a later pending one; the superseded heap entry cannot be removed
    /// from the `BinaryHeap` and is dropped at pop time instead (see
    /// `take_due_wake`).
    wake_pending: Vec<Option<f64>>,
    /// Wake bookkeeping: honored pops vs superseded (stale) pops.
    wakes_executed: u64,
    wakes_stale_dropped: u64,
    /// Incremental-scheduler dirty tracking: groups whose membership,
    /// deadline anchor, or member states changed since the last pass.
    /// `BTreeSet` for deterministic iteration order.
    dirty_groups: BTreeSet<GroupId>,
    /// Groups that drained (all members complete) since the last pass.
    removed_groups: Vec<GroupId>,
    /// Force the next pass down the full-solve path (instance failures
    /// change the view set; the cached plan is unusable).
    sched_force_full: bool,
    /// Hardware-profiled Θ per (gpu, model) — §6 Offline Profiling.
    thetas: ThetaCache,
    /// End time of each instance's in-flight iteration: a step is an
    /// atomic unit of GPU work; wakes landing inside it are deferred.
    next_free: Vec<f64>,
    /// Scheduler views, built once and refreshed in place per pass
    /// (dead instances are dropped on failure).
    views_cache: Vec<InstanceView>,
    /// Scale-down in progress: the instance receives no new work and
    /// leaves the fleet once its running batch drains (no mid-flight
    /// kills). Dense, indexed by `InstanceId.0` like `alive`.
    draining: Vec<bool>,
    /// When each instance joined the fleet (0 for the starting fleet,
    /// cold-start completion for provisioned ones) / left it — the
    /// device-seconds ledger.
    commissioned_at: Vec<f64>,
    decommissioned_at: Vec<Option<f64>>,
    /// Provisioned instances still in their cold-start window.
    warming: u32,
    autoscaler: Option<Autoscaler>,
    admission: AdmissionController,
    /// Waiting (+ evicted) request counts per (class, model, mega),
    /// maintained incrementally at every state transition — the
    /// autoscaler's and admission controller's backlog signal without
    /// any per-pass walk. Mega is in the key because the profile table
    /// is: mega output moments are several times larger, and pricing a
    /// mega backlog with the regular profile would underestimate drain
    /// times exactly when the pressure signal matters most.
    /// `BTreeMap` so pressure sums fold in a deterministic order.
    waiting_by: BTreeMap<(SloClass, ModelId, bool), i64>,
    /// Open-group index: groups with spare capacity per
    /// (model, class, mega). Makes `classify_in_place` O(1) per arrival
    /// instead of a scan of the live group table; `BTreeSet` keeps the
    /// lowest-id-wins rule of the scan it replaces.
    open_groups: HashMap<(ModelId, SloClass, bool), BTreeSet<GroupId>>,
}

impl Simulation {
    pub fn new(cfg: SimConfig, trace: &Trace) -> Self {
        // Workload profiling (§6, Offline Profiling): moments from the
        // request history dataset — we use the trace itself as history.
        let mut profiles = ProfileTable::from_trace(trace);
        if cfg.policy.conservative_estimator() {
            // SHEPHERD-style deterministic worst-case estimates: every
            // request is assumed to run to the max output length.
            profiles = conservative(&profiles, trace);
        }
        let estimator = RwtEstimator::new(profiles);
        let solver = match cfg.policy {
            Policy::Qlm { solver, .. } => solver,
            _ => SolverKind::Greedy,
        };
        let scheduler = GlobalScheduler::new(
            SchedulerConfig {
                solver,
                incremental: cfg.sched_incremental,
                ..Default::default()
            },
            estimator,
        );
        let instances: Vec<Instance> = cfg
            .fleet
            .iter()
            .map(|c| Instance::new(c.clone(), cfg.catalog.clone()))
            .collect();
        // Dense indexing requires the fleet builders' sequential ids.
        for (idx, inst) in instances.iter().enumerate() {
            debug_assert_eq!(inst.config.id.0 as usize, idx, "fleet ids must be dense");
        }
        let vqs = instances
            .iter()
            .map(|i| VirtualQueue::new(i.config.id))
            .collect();
        let lso = cfg.policy.lso();
        let agents = instances
            .iter()
            .map(|i| QlmAgent::new(i.config.id, lso))
            .collect();
        let grouper = Grouper::new(cfg.delta, cfg.avg_batch, cfg.seed ^ 0x9E37);
        let n_instances = instances.len();
        // Autoscaling needs the group/virtual-queue machinery; baseline
        // per-request policies keep their fixed fleet.
        let autoscaler = cfg
            .autoscale
            .filter(|_| cfg.policy.uses_groups())
            .map(Autoscaler::new);
        let admission = AdmissionController::new(cfg.admission);
        let mut sim = Simulation {
            now: 0.0,
            seq: 0,
            events: BinaryHeap::new(),
            instances,
            vqs,
            agents,
            alive: vec![true; n_instances],
            queue: GlobalQueue::new(),
            groups: HashMap::new(),
            group_of: HashMap::new(),
            grouper,
            scheduler,
            pinned_model: HashMap::new(),
            needs_schedule: false,
            last_schedule: -1e9,
            scheduler_wall_s: 0.0,
            scheduler_invocations: 0,
            wake_pending: vec![None; n_instances],
            wakes_executed: 0,
            wakes_stale_dropped: 0,
            dirty_groups: BTreeSet::new(),
            removed_groups: Vec::new(),
            sched_force_full: false,
            thetas: ThetaCache::new(),
            next_free: vec![0.0; n_instances],
            views_cache: Vec::new(),
            draining: vec![false; n_instances],
            commissioned_at: vec![0.0; n_instances],
            decommissioned_at: vec![None; n_instances],
            warming: 0,
            autoscaler,
            admission,
            waiting_by: BTreeMap::new(),
            open_groups: HashMap::new(),
            cfg,
        };
        sim.init_pinning(trace);
        sim.build_views();
        for (i, r) in trace.requests.iter().enumerate() {
            sim.push_event(r.arrival_s, EventKind::Arrival(i));
        }
        let failures = sim.cfg.failures.clone();
        for (t, inst) in failures {
            sim.push_event(t, EventKind::Fail(inst));
        }
        sim
    }

    fn push_event(&mut self, t: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Reverse(Event {
            t,
            seq: self.seq,
            kind,
        }));
    }

    fn wake(&mut self, id: InstanceId, t: f64) {
        let idx = id.0 as usize;
        if !self.alive[idx] {
            return;
        }
        // Coalesce: skip if an earlier-or-equal wake is already pending.
        // When an *earlier* wake supersedes a pending later one, the
        // later heap entry stays behind and is discarded at pop time by
        // `take_due_wake`.
        if let Some(pending) = self.wake_pending[idx] {
            if pending <= t + 1e-12 {
                return;
            }
        }
        self.wake_pending[idx] = Some(t);
        self.push_event(t, EventKind::Wake(id));
    }

    /// Pop-side half of the wake dedup: honor a popped Wake only if it
    /// *is* the currently pending wake for the instance. Superseded
    /// entries used to clear `wake_pending` and fire a spurious
    /// `on_wake` anyway, breaking the at-most-one-pending-Wake
    /// invariant (a stale pop would also cancel a legitimately pending
    /// newer wake, duplicating iterations at the old time).
    fn take_due_wake(&mut self, id: InstanceId, t: f64) -> bool {
        let idx = id.0 as usize;
        match self.wake_pending[idx] {
            Some(pending) if (pending - t).abs() <= 1e-12 => {
                self.wake_pending[idx] = None;
                self.wakes_executed += 1;
                true
            }
            _ => {
                self.wakes_stale_dropped += 1;
                false
            }
        }
    }

    /// (honored, stale-dropped) wake pops — observability for the
    /// at-most-one-pending-Wake invariant.
    pub fn wake_stats(&self) -> (u64, u64) {
        (self.wakes_executed, self.wakes_stale_dropped)
    }

    /// Static model placement for policies without model swapping:
    /// distribute instances over models proportionally to request share
    /// (what an operator running vanilla vLLM would provision).
    fn init_pinning(&mut self, trace: &Trace) {
        if self.cfg.policy.lso().model_swapping {
            return;
        }
        let mut counts: HashMap<ModelId, usize> = HashMap::new();
        for r in &trace.requests {
            *counts.entry(r.model).or_default() += 1;
        }
        let mut models: Vec<(ModelId, usize)> = counts.into_iter().collect();
        models.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let total: usize = models.iter().map(|(_, c)| c).sum();
        let n_inst = self.instances.len();
        // Quota per model (≥1), largest first.
        let mut quota: Vec<(ModelId, usize)> = models
            .iter()
            .map(|&(m, c)| {
                let q = (c as f64 / total as f64) * n_inst as f64;
                (m, q.round().max(1.0) as usize)
            })
            .collect();
        // Trim/extend to exactly n_inst.
        let mut assigned: usize = quota.iter().map(|(_, q)| q).sum();
        let mut i = 0;
        let nq = quota.len();
        while assigned > n_inst && nq > 0 {
            // Prefer shrinking an over-provisioned model; if every quota
            // is already 1 (more models than instances), drop the least
            // popular model entirely — static provisioning cannot serve
            // more models than it has instances.
            if let Some(k) = (0..nq).filter(|&k| quota[k].1 > 1).max_by_key(|&k| quota[k].1)
            {
                quota[k].1 -= 1;
            } else if let Some(k) = (0..nq).rev().find(|&k| quota[k].1 == 1) {
                quota[k].1 = 0;
            } else {
                break;
            }
            assigned -= 1;
        }
        while assigned < n_inst && nq > 0 {
            quota[i % nq].1 += 1;
            assigned += 1;
            i += 1;
        }
        // Pin: each instance gets the next model with remaining quota it
        // can actually serve.
        let catalog = self.cfg.catalog.clone();
        for inst in &mut self.instances {
            let gpu = inst.config.gpu;
            let pick = quota
                .iter_mut()
                .find(|(m, q)| *q > 0 && PerfModel::fits(catalog.get(*m), gpu))
                .map(|e| {
                    e.1 -= 1;
                    e.0
                })
                .or_else(|| {
                    quota
                        .iter()
                        .map(|&(m, _)| m)
                        .find(|&m| PerfModel::fits(catalog.get(m), gpu))
                });
            if let Some(m) = pick {
                self.pinned_model.insert(inst.config.id, m);
                let (_ready, displaced) = inst.swap_model(m, 0.0);
                debug_assert!(displaced.is_empty());
            }
        }
    }

    /// Build one instance's scheduler view: `perf_for` is static per
    /// (instance, model); only swap times, active model, and the
    /// executing group change between passes.
    fn build_view_for(&mut self, idx: usize) -> InstanceView {
        let catalog = self.cfg.catalog.clone();
        let inst = &self.instances[idx];
        let id = inst.config.id;
        let gpu = inst.config.gpu;
        let mut perf_for = HashMap::new();
        let mut swap_time = HashMap::new();
        for m in catalog.ids() {
            // Pinned instances only serve their pinned model.
            if let Some(&pm) = self.pinned_model.get(&id) {
                if pm != m {
                    continue;
                }
            }
            let prompt = crate::backend::perf::PROFILE_MEAN_PROMPT_TOKENS;
            if let Some(p) = self.thetas.perf(gpu, m, &catalog, prompt) {
                let inst = &self.instances[idx];
                swap_time.insert(m, inst.registry().swap_in_time_s(m, &p));
                perf_for.insert(m, p);
            }
        }
        let inst = &self.instances[idx];
        InstanceView {
            id,
            active_model: inst.active_model(),
            perf_for,
            swap_time,
            executing: None,
        }
    }

    /// Build the scheduler views once at startup.
    fn build_views(&mut self) {
        let views: Vec<InstanceView> = (0..self.instances.len())
            .map(|idx| self.build_view_for(idx))
            .collect();
        self.views_cache = views;
    }

    /// Refresh the cached views in place for one scheduler pass. Returns
    /// the views by value (callers put them back via `views_cache`) so
    /// the scheduling methods can borrow `self` mutably alongside them.
    fn refresh_views(&mut self) -> Vec<InstanceView> {
        let mut views = std::mem::take(&mut self.views_cache);
        views.retain(|v| self.alive[v.id.0 as usize]);
        for v in views.iter_mut() {
            let inst = &self.instances[v.id.0 as usize];
            v.active_model = inst.active_model();
            v.executing = inst
                .running()
                .first()
                .and_then(|s| self.group_of.get(&s.req_id).copied());
            // Swap-in times depend on each model's current tier.
            for (m, t) in v.swap_time.iter_mut() {
                let p = v.perf_for[m];
                *t = inst.registry().swap_in_time_s(*m, &p);
            }
        }
        views
    }

    /// Run to completion (all requests served) or the horizon.
    pub fn run(mut self, trace: &Trace) -> RunMetrics {
        let total = trace.len();
        while let Some(Reverse(ev)) = self.events.pop() {
            if ev.t > self.cfg.horizon_s {
                // Horizon hit: still register any not-yet-arrived requests
                // so metrics count them (as violations if unserved).
                if let EventKind::Arrival(i) = ev.kind {
                    let req = Request::from_trace(0, &trace.requests[i]);
                    self.queue.submit(req);
                }
                while let Some(Reverse(e2)) = self.events.pop() {
                    if let EventKind::Arrival(i) = e2.kind {
                        let req = Request::from_trace(0, &trace.requests[i]);
                        self.queue.submit(req);
                    }
                }
                break;
            }
            self.now = ev.t;
            match ev.kind {
                EventKind::Arrival(i) => self.on_arrival(&trace.requests[i]),
                EventKind::Wake(id) => {
                    // A stale (superseded) pop fires no on_wake, but
                    // still falls through to maybe_schedule below — an
                    // interval-deferred pending schedule must not be
                    // dropped along with the event.
                    if self.take_due_wake(id, ev.t) {
                        self.on_wake(id);
                    }
                }
                EventKind::Fail(id) => self.on_fail(id),
                EventKind::Provision(id) => self.on_provision(id),
            }
            self.maybe_schedule();
            if self.queue.completed.len() + self.queue.len_shed() == total {
                break;
            }
        }
        self.finish()
    }

    /// Adjust the per-(class, model) waiting counter for request `rid`.
    /// The request must still be resident in the broker.
    fn note_waiting(&mut self, rid: u64, delta: i64) {
        if let Some(r) = self.queue.get(rid) {
            *self
                .waiting_by
                .entry((r.class, r.model, r.mega))
                .or_default() += delta;
        }
    }

    fn on_arrival(&mut self, tr: &crate::workload::TraceRequest) {
        let req = Request::from_trace(0, tr);
        let id = self.queue.submit(req);
        // Admission control: a hopeless batch class is refused at the
        // door — recorded as shed, never grouped, never scheduled — so
        // its backlog cannot poison the penalty signal for requests
        // that still have a chance.
        if self.admission.should_shed(tr.class) {
            self.queue.shed(id);
            self.admission.note_shed_submit();
            return;
        }
        let req = self.queue.get(id).unwrap().clone();
        self.note_waiting(id, 1);
        // Group formation (§4).
        let gid = if self.cfg.policy.uses_groups() {
            // §Perf: classify in place against the open-group index
            // (cloning every live group per arrival was
            // O(groups × members); scanning the live table was
            // O(groups) — both cap queue scale).
            self.classify_in_place(&req)
        } else {
            // Per-request singleton groups (EDF / vLLM): id = request id,
            // which preserves FCFS order across groups.
            let gid = GroupId(id);
            self.groups.insert(
                gid,
                RequestGroup {
                    id: gid,
                    model: req.model,
                    class: req.class,
                    slo_s: req.slo_s,
                    earliest_arrival_s: req.arrival_s,
                    members: VecDeque::from([id]),
                    mega: req.mega,
                },
            );
            gid
        };
        self.group_of.insert(id, gid);
        self.dirty_groups.insert(gid);
        self.needs_schedule = true;
        self.wake_idle();
    }

    /// Incremental request-group classification (§4, Handling New
    /// Incoming Requests) through the open-group index: O(1) per
    /// arrival. The index holds, per (model, class, mega), exactly the
    /// live groups with spare capacity; taking the `BTreeSet` minimum
    /// reproduces the lowest-id-wins rule of the table scan this
    /// replaces, so placement stays independent of hash-map iteration
    /// order — and no longer scales with the live group count (the
    /// autoscale scenario's churn regime, ROADMAP open item).
    fn classify_in_place(&mut self, req: &Request) -> GroupId {
        let cap = self.grouper.max_group_size();
        let key = (req.model, req.class, req.mega);
        if let Some(set) = self.open_groups.get_mut(&key) {
            if let Some(&gid) = set.iter().next() {
                let g = self.groups.get_mut(&gid).expect("open-group index is live");
                debug_assert!(g.len() < cap, "index must only hold open groups");
                g.members.push_back(req.id);
                g.slo_s = g.slo_s.min(req.slo_s);
                g.earliest_arrival_s = g.earliest_arrival_s.min(req.arrival_s);
                if g.len() >= cap {
                    set.remove(&gid);
                }
                return gid;
            }
        }
        let mut list = Vec::new();
        let gid = self.grouper.classify(req, &mut list);
        let g = list.pop().unwrap();
        let open = g.len() < cap;
        self.groups.insert(gid, g);
        if open {
            self.open_groups.entry(key).or_default().insert(gid);
        }
        gid
    }

    fn wake_idle(&mut self) {
        let ids: Vec<InstanceId> = self
            .instances
            .iter()
            .filter(|i| self.alive[i.config.id.0 as usize] && i.is_idle())
            .map(|i| i.config.id)
            .collect();
        for id in ids {
            let t = self.now.max(self.inst(id).busy_until());
            self.wake(id, t);
        }
    }

    fn inst(&self, id: InstanceId) -> &Instance {
        &self.instances[id.0 as usize]
    }

    fn inst_mut(&mut self, id: InstanceId) -> &mut Instance {
        &mut self.instances[id.0 as usize]
    }

    fn observation(&self, id: InstanceId) -> InstanceObservation {
        let inst = self.inst(id);
        let running = inst
            .running()
            .iter()
            .filter_map(|s| self.group_of.get(&s.req_id).map(|&g| (s.req_id, g)))
            .collect();
        // vLLM semantics: internally preempted (swapped) sequences have
        // strict priority over new admissions — while any exist, the
        // instance is considered full. Without this gate, fresh prompts
        // steal the blocks preempted sequences need and TTFT collapses to
        // prefill time while per-request progress starves.
        let spare = if inst.swapped_len() > 0 {
            0
        } else {
            inst.spare_tokens()
        };
        InstanceObservation {
            id,
            active_model: inst.active_model(),
            swapping: inst.is_swapping(self.now),
            running,
            spare_capacity_tokens: spare,
            batch_slots_free: inst.batch_slots_free(),
        }
    }

    fn on_wake(&mut self, id: InstanceId) {
        let idx = id.0 as usize;
        if !self.alive[idx] {
            return;
        }
        // Draining (scale-down): once the remaining batch completes, the
        // instance leaves the fleet. Until then it keeps stepping but
        // admits nothing new.
        if self.draining[idx] && self.inst(id).is_idle() {
            self.decommission(id);
            return;
        }
        // Mid-swap: try again when the swap completes.
        let busy_until = self.inst(id).busy_until();
        if self.now < busy_until {
            self.wake(id, busy_until);
            return;
        }
        // Mid-iteration: a decode step is atomic GPU work; defer.
        let free_at = self.next_free[idx];
        if self.now < free_at - 1e-12 {
            self.wake(id, free_at);
            return;
        }

        // SHEPHERD fixed batches: only admit when the batch fully drained.
        let fixed = self.cfg.policy.fixed_batches();
        let can_admit = !self.draining[idx] && (!fixed || self.inst(id).running_len() == 0);

        if can_admit {
            // §Perf: the agent reads the live virtual queue and group
            // table by reference — the seed cloned both on every wake.
            let vq = &self.vqs[idx];
            let obs = self.observation(id);
            let agent = &self.agents[idx];
            let queue_ref = &self.queue;
            let groups_ref = &self.groups;
            let profiles_ref = &self.scheduler.estimator.profiles;
            let actions = agent.decide(
                vq,
                groups_ref,
                |g| waiting_members(groups_ref, queue_ref, g),
                &obs,
                |rid| {
                    queue_ref
                        .get(rid)
                        .map(|r| {
                            if fixed {
                                // SHEPHERD-style fixed batches must be
                                // sized for the deterministic worst case:
                                // prompt + max output tokens (this is the
                                // under-utilization Fig. 1 critiques).
                                let prof = profiles_ref.get(r.model, r.class, r.mega);
                                r.input_tokens as u64 + prof.max_out as u64
                            } else {
                                (r.input_tokens + r.generated) as u64
                            }
                        })
                        .unwrap_or(0)
                },
            );
            self.apply_actions(id, actions);
        }

        // One continuous-batching iteration.
        let now = self.now;
        let out = self.inst_mut(id).step(now);
        for (rid, t) in &out.first_tokens {
            self.queue.record_first_token(*rid, *t);
        }
        let t_done = self.now + out.dt;
        for seq in out.completed {
            self.queue.complete(seq.req_id, seq.first_token_at, t_done);
            self.on_request_done(seq.req_id, id);
        }
        if out.dt > 0.0 {
            self.next_free[idx] = t_done;
            self.wake(id, t_done);
        } else if !self.inst(id).is_idle() {
            // Has swapped-out work but no progress possible; re-check soon.
            self.wake(id, self.now + 0.05);
        }
    }

    fn apply_actions(&mut self, id: InstanceId, actions: Vec<LsoAction>) {
        for a in actions {
            match a {
                LsoAction::SwapModel { model, .. } => {
                    let now = self.now;
                    let (ready, displaced) = self.inst_mut(id).swap_model(model, now);
                    for seq in displaced {
                        self.queue.requeue_evicted(seq.req_id, seq.generated, id);
                        self.note_waiting(seq.req_id, 1);
                        if let Some(&g) = self.group_of.get(&seq.req_id) {
                            self.dirty_groups.insert(g);
                        }
                    }
                    // Warm-set update from the vq's model order (§5).
                    let order: Vec<ModelId> = {
                        let vq = &self.vqs[id.0 as usize];
                        let groups = &self.groups;
                        vq.model_order(|g| groups.get(&g))
                    };
                    self.inst_mut(id).registry_mut().set_warm_set(&order);
                    self.wake(id, ready);
                }
                LsoAction::Evict { requests, .. } => {
                    let now = self.now;
                    let evicted = self.inst_mut(id).evict(&requests, now);
                    for seq in evicted {
                        self.queue.requeue_evicted(seq.req_id, seq.generated, id);
                        self.note_waiting(seq.req_id, 1);
                        if let Some(&g) = self.group_of.get(&seq.req_id) {
                            self.dirty_groups.insert(g);
                        }
                    }
                    self.needs_schedule = true;
                }
                LsoAction::Pull { request, .. } => {
                    let Some(r) = self.queue.get(request) else {
                        continue;
                    };
                    let seq = RunningSeq {
                        req_id: r.id,
                        model: r.model,
                        prompt_tokens: r.input_tokens,
                        target_output: r.output_tokens_hidden.max(1),
                        generated: r.generated,
                        first_token_at: r.first_token_s,
                        arrival_s: r.arrival_s,
                    };
                    let now = self.now;
                    let res = if r.evicted_from == Some(id) {
                        self.inst_mut(id).try_restore(seq, now)
                    } else {
                        self.inst_mut(id).try_admit(seq, now)
                    };
                    if res.is_ok() {
                        self.note_waiting(request, -1);
                        self.queue.mark_running(request);
                        // The group's earliest *unserved* member may have
                        // changed — re-anchor it at the next pass.
                        if let Some(&g) = self.group_of.get(&request) {
                            self.dirty_groups.insert(g);
                        }
                    }
                }
            }
        }
    }

    /// Instance failure (§4 Fault Isolation): the device is gone. Its
    /// virtual queue is dropped — by design it can be rebuilt from the
    /// global queue alone — and every request that was on the instance
    /// reverts to Waiting with progress discarded.
    fn on_fail(&mut self, id: InstanceId) {
        let idx = id.0 as usize;
        if !self.alive[idx] {
            return;
        }
        self.alive[idx] = false;
        self.wake_pending[idx] = None;
        if self.decommissioned_at[idx].is_none() {
            self.decommissioned_at[idx] = Some(self.now);
        }
        let lost = self.inst_mut(id).fail();
        let lost_ids: Vec<u64> = lost.iter().map(|s| s.req_id).collect();
        for rid in &lost_ids {
            if let Some(&g) = self.group_of.get(rid) {
                self.dirty_groups.insert(g);
            }
        }
        self.queue.fail_instance(id, &lost_ids);
        for rid in &lost_ids {
            self.note_waiting(*rid, 1);
        }
        self.vqs[idx].set_order(Vec::new());
        self.views_cache.retain(|v| v.id != id);
        // Reschedule immediately, down the full-solve path: the view set
        // shrank, so the incremental cache is unusable.
        self.sched_force_full = true;
        self.needs_schedule = true;
        self.last_schedule = -1e9;
    }

    /// Provision one instance (autoscaler scale-up). The cold start is
    /// the weight-staging time of the model the scale-up is for
    /// (storage → CPU, priced by the perf model); the instance joins
    /// the fleet with those weights warm in host memory, so its first
    /// SwapModel LSO pays only the CPU → GPU hop.
    fn provision_instance(&mut self, model: ModelId) {
        let gpu = self.cfg.autoscale.expect("autoscaler requires config").gpu;
        // A tier that can host nothing in the catalog would add a device
        // that serves no model at all — refuse rather than burn
        // device-hours on it (misconfigured AutoscaleConfig::gpu).
        let serves_any = self
            .cfg
            .catalog
            .ids()
            .into_iter()
            .any(|m| PerfModel::fits(self.cfg.catalog.get(m), gpu));
        if !serves_any {
            return;
        }
        let id = InstanceId(self.instances.len() as u32);
        let mut inst = Instance::new(InstanceConfig::new(id.0, gpu), self.cfg.catalog.clone());
        let prompt = crate::backend::perf::PROFILE_MEAN_PROMPT_TOKENS;
        let delay = PerfModel::try_profile(self.cfg.catalog.get(model), gpu, prompt)
            .map(|p| p.swap_storage_cpu_s)
            .unwrap_or(30.0);
        inst.registry_mut().set_warm_set(&[model]);
        let ready = self.now + delay;
        self.instances.push(inst);
        self.vqs.push(VirtualQueue::new(id));
        self.agents.push(QlmAgent::new(id, self.cfg.policy.lso()));
        self.alive.push(false);
        self.draining.push(false);
        self.wake_pending.push(None);
        self.next_free.push(0.0);
        self.commissioned_at.push(ready);
        self.decommissioned_at.push(None);
        self.warming += 1;
        self.push_event(ready, EventKind::Provision(id));
    }

    /// Cold start finished: the instance joins the scheduler's view set
    /// (a view-set change — the incremental cache is unusable, exactly
    /// as on failure, so the next pass full-solves).
    fn on_provision(&mut self, id: InstanceId) {
        let idx = id.0 as usize;
        self.warming = self.warming.saturating_sub(1);
        self.alive[idx] = true;
        let view = self.build_view_for(idx);
        self.views_cache.push(view);
        self.sched_force_full = true;
        self.needs_schedule = true;
        self.last_schedule = -1e9;
        self.wake(id, self.now);
    }

    /// Scale down by draining: the victim leaves the scheduler's view
    /// set immediately (view-set change ⇒ full solve reassigns its
    /// queued groups), keeps stepping its running batch to completion,
    /// and is decommissioned when idle. No request is killed mid-flight.
    fn begin_drain(&mut self) {
        let victim = (0..self.instances.len())
            .filter(|&i| self.alive[i] && !self.draining[i])
            .max_by_key(|&i| (self.instances[i].is_idle(), i))
            .map(|i| InstanceId(i as u32));
        let Some(id) = victim else { return };
        let idx = id.0 as usize;
        self.draining[idx] = true;
        self.views_cache.retain(|v| v.id != id);
        // Its queued groups must be reassigned; mark them dirty (the
        // forced full solve re-places everything anyway, but the dirt
        // keeps delta-path bookkeeping consistent).
        let held: Vec<GroupId> = self.vqs[idx].groups.iter().copied().collect();
        for g in held {
            if self.groups.contains_key(&g) {
                self.dirty_groups.insert(g);
            }
        }
        self.vqs[idx].set_order(Vec::new());
        self.sched_force_full = true;
        self.needs_schedule = true;
        if self.inst(id).is_idle() {
            self.decommission(id);
        }
    }

    /// A drained instance leaves the fleet for good.
    fn decommission(&mut self, id: InstanceId) {
        let idx = id.0 as usize;
        if !self.alive[idx] {
            return;
        }
        debug_assert!(self.inst(id).is_idle(), "decommission requires a drained batch");
        self.alive[idx] = false;
        self.wake_pending[idx] = None;
        self.decommissioned_at[idx] = Some(self.now);
        // KV this instance parked for previously evicted requests is
        // gone with it; those requests are still Waiting in the broker
        // (single replica, §4) and restart from their prompt elsewhere.
        self.queue.fail_instance(id, &[]);
    }

    /// Per-class backlog pressure from the incremental waiting counters:
    /// predicted drain time = pending output tokens of this class and
    /// every tighter class over the fleet's aggregate Θ — the
    /// RWT-estimator waiting model (Eq. 2) applied fleet-wide.
    ///
    /// `fit_gpu` restricts each class's `hottest_model` to models that
    /// fit that tier, so a scale-up never warms (or is sized for) a
    /// model the provisioned device cannot host.
    fn class_pressures(&self, fit_gpu: Option<crate::backend::GpuKind>) -> Vec<ClassPressure> {
        // Aggregate Θ over active (non-draining) instances: each runs
        // its most capable model at the profile-mean footprint.
        let profiles = &self.scheduler.estimator.profiles;
        let mut fleet_theta = 0.0;
        for v in &self.views_cache {
            let best = v
                .perf_for
                .iter()
                .map(|(m, p)| {
                    let prof = profiles.get(*m, SloClass::Interactive, false);
                    p.steady_throughput(prof.mean_tokens_per_req())
                })
                .fold(0.0_f64, f64::max);
            fleet_theta += best;
        }
        let mut out = Vec::with_capacity(SloClass::ALL.len());
        let mut cum_tokens = 0.0;
        for class in SloClass::ALL {
            let mut waiting = 0usize;
            let mut tokens = 0.0;
            // Per-model totals (mega + non-mega summed) over hostable
            // models — a model's backlog must not lose the hottest pick
            // because it was split across mega variants.
            let mut per_model: BTreeMap<ModelId, i64> = BTreeMap::new();
            for (&(c, m, mega), &n) in &self.waiting_by {
                if c != class || n <= 0 {
                    continue;
                }
                waiting += n as usize;
                tokens += n as f64 * profiles.get(m, c, mega).mu_out;
                let hostable = fit_gpu
                    .map(|g| PerfModel::fits(self.cfg.catalog.get(m), g))
                    .unwrap_or(true);
                if hostable {
                    *per_model.entry(m).or_default() += n;
                }
            }
            // Ascending iteration + strict `>` keeps the lowest model
            // id on ties.
            let mut hottest: Option<(ModelId, i64)> = None;
            for (&m, &n) in &per_model {
                if hottest.map(|(_, hn)| n > hn).unwrap_or(true) {
                    hottest = Some((m, n));
                }
            }
            cum_tokens += tokens;
            let drain_s = if cum_tokens <= 0.0 {
                0.0
            } else if fleet_theta > 0.0 {
                cum_tokens / fleet_theta
            } else {
                f64::INFINITY
            };
            out.push(ClassPressure {
                class,
                waiting,
                drain_s,
                hottest_model: hottest.map(|(m, _)| m),
            });
        }
        out
    }

    /// One capacity-subsystem evaluation, run after every scheduler
    /// pass: update the admission gates and let the autoscaler act.
    /// Free when the whole subsystem is off — the pressure walk must
    /// not tax runs (or Fig. 20 overhead numbers) that never asked for
    /// capacity management.
    fn capacity_tick(&mut self) {
        if self.autoscaler.is_none() && !self.admission.cfg.enabled {
            return;
        }
        let tier = self.autoscaler.as_ref().map(|a| a.cfg.gpu);
        let pressures = self.class_pressures(tier);
        let active = (0..self.instances.len())
            .filter(|&i| self.alive[i] && !self.draining[i])
            .count() as u32;
        let draining = (0..self.instances.len())
            .filter(|&i| self.alive[i] && self.draining[i])
            .count() as u32;
        // "Maxed" for admission purposes means growth cannot help: the
        // instance budget is exhausted, or nothing backlogged fits the
        // provisionable tier (hottest_model is tier-filtered) — in
        // either case waiting for more capacity would be waiting for
        // capacity that can never serve the backlog.
        let fleet_maxed = match &self.autoscaler {
            Some(a) => {
                let at_max = active + self.warming + draining >= a.cfg.max_instances;
                let growth_helps = pressures
                    .iter()
                    .any(|p| p.waiting > 0 && p.hottest_model.is_some());
                at_max || !growth_helps
            }
            None => true, // a fixed fleet cannot grow
        };
        let drains: Vec<(SloClass, f64)> = pressures.iter().map(|p| (p.class, p.drain_s)).collect();
        self.admission.update(&drains, fleet_maxed);
        let any_idle = (0..self.instances.len())
            .any(|i| self.alive[i] && !self.draining[i] && self.instances[i].is_idle());
        let warming = self.warming;
        let decision = match self.autoscaler.as_mut() {
            Some(a) => a.decide(self.now, &pressures, active, warming, draining, any_idle),
            None => ScaleDecision::Hold,
        };
        match decision {
            ScaleDecision::Up { count, model } => {
                for _ in 0..count {
                    self.provision_instance(model);
                }
            }
            ScaleDecision::Down => self.begin_drain(),
            ScaleDecision::Hold => {}
        }
    }

    /// Retire groups the scheduler reported as unservable (no instance
    /// can serve their model) through the admission controller, so shed
    /// and unservable requests share one accounting path. Their waiting
    /// members are shed in the broker (recorded once, as violations)
    /// and the group dissolves; next pass's delta sees a removal.
    ///
    /// A group is only retired when no fleet growth could rescue it: if
    /// the autoscaler can still provision a tier that hosts the model,
    /// the group is left queued — its backlog pressure drives the
    /// scale-up that makes it servable again (shedding recoverable work
    /// early would throw requests away, the same rule the admission
    /// controller applies at submit time).
    fn shed_unservable_groups(&mut self, unservable: Vec<GroupId>) {
        let rescue_tier = match &self.autoscaler {
            Some(a) => {
                let powered = (0..self.instances.len())
                    .filter(|&i| self.alive[i])
                    .count() as u32
                    + self.warming;
                if powered < a.cfg.max_instances {
                    Some(a.cfg.gpu)
                } else {
                    None
                }
            }
            None => None,
        };
        for gid in unservable {
            let Some(g) = self.groups.get(&gid) else { continue };
            if let Some(gpu) = rescue_tier {
                if PerfModel::fits(self.cfg.catalog.get(g.model), gpu) {
                    continue; // a future scale-up can serve this group
                }
            }
            let key = (g.model, g.class, g.mega);
            let members: Vec<u64> = g.members.iter().copied().collect();
            let mut shed = 0u64;
            for rid in members {
                if self.queue.shed(rid) {
                    self.note_waiting(rid, -1);
                    self.group_of.remove(&rid);
                    shed += 1;
                }
            }
            self.admission.note_shed_unservable(shed);
            let empty = {
                let g = self.groups.get_mut(&gid).unwrap();
                let group_of = &self.group_of;
                g.members.retain(|rid| group_of.contains_key(rid));
                g.is_empty()
            };
            if empty {
                self.groups.remove(&gid);
                if let Some(set) = self.open_groups.get_mut(&key) {
                    set.remove(&gid);
                }
                for vq in self.vqs.iter_mut() {
                    vq.remove(gid);
                }
                self.dirty_groups.remove(&gid);
                self.removed_groups.push(gid);
                self.scheduler.estimator.forget_group(gid);
            }
        }
    }

    /// Request finished: drop from its group; empty groups leave their
    /// virtual queue (§4: groups dequeue when all requests complete).
    fn on_request_done(&mut self, rid: u64, _inst: InstanceId) {
        let Some(gid) = self.group_of.remove(&rid) else {
            return;
        };
        let grouped = self.cfg.policy.uses_groups();
        let cap = self.grouper.max_group_size();
        let (empty, key) = {
            let Some(g) = self.groups.get_mut(&gid) else {
                return;
            };
            g.members.retain(|&m| m != rid);
            (g.is_empty(), (g.model, g.class, g.mega))
        };
        if empty {
            self.groups.remove(&gid);
            if grouped {
                if let Some(set) = self.open_groups.get_mut(&key) {
                    set.remove(&gid);
                }
            }
            for vq in self.vqs.iter_mut() {
                vq.remove(gid);
            }
            // The group is gone: its scheduler-cache entry and memoized
            // service prices go with it.
            self.dirty_groups.remove(&gid);
            self.removed_groups.push(gid);
            self.scheduler.estimator.forget_group(gid);
            self.needs_schedule = true;
        } else {
            // Shrunk group: it has room again (open-group index), and it
            // must be re-priced and re-anchored at the next pass.
            if grouped && self.groups[&gid].len() < cap {
                self.open_groups.entry(key).or_default().insert(gid);
            }
            self.dirty_groups.insert(gid);
        }
    }

    fn maybe_schedule(&mut self) {
        if !self.needs_schedule
            || self.now - self.last_schedule < self.cfg.sched_interval_s
        {
            return;
        }
        self.needs_schedule = false;
        self.last_schedule = self.now;
        // Re-anchor each group's deadline to its earliest *unserved*
        // member: served members have their TTFT already, so a group's
        // binding constraint is the oldest request still waiting. Without
        // this, long-lived batch groups permanently outrank fresh
        // interactive arrivals in deadline order.
        //
        // §Perf: only dirty groups are re-walked. The earliest unserved
        // member can only change when a member transitions state
        // (arrival, pull, evict, completion, failure) — and every one of
        // those marks the group dirty — so this is equivalent to the old
        // all-groups walk, which was O(all queued requests) per pass and
        // capped queue scale.
        let earliest: Vec<(GroupId, f64)> = self
            .dirty_groups
            .iter()
            .filter_map(|gid| self.groups.get(gid))
            .map(|g| {
                let e = g
                    .members
                    .iter()
                    .filter(|&&m| {
                        self.queue
                            .get(m)
                            .map(|r| {
                                matches!(
                                    r.state,
                                    RequestState::Waiting | RequestState::Evicted
                                )
                            })
                            .unwrap_or(false)
                    })
                    .filter_map(|&m| self.queue.get(m).map(|r| r.arrival_s))
                    .fold(f64::INFINITY, f64::min);
                (g.id, e)
            })
            .collect();
        for (gid, e) in earliest {
            if e.is_finite() {
                if let Some(g) = self.groups.get_mut(&gid) {
                    g.earliest_arrival_s = e;
                }
            }
        }
        let wall = WallInstant::now();

        let views = self.refresh_views();
        let unservable = match self.cfg.policy {
            Policy::VllmFcfs => {
                self.schedule_fcfs(&views);
                Vec::new()
            }
            Policy::Edf => {
                self.schedule_edf(&views);
                Vec::new()
            }
            Policy::Qlm { lso, .. } if !lso.load_balancing => {
                self.schedule_round_robin(&views);
                Vec::new()
            }
            _ => self.schedule_qlm(&views),
        };
        self.views_cache = views;
        // Every policy consumes (or rebuilds from scratch over) the full
        // group table per pass, so the dirt is spent either way.
        self.dirty_groups.clear();
        self.removed_groups.clear();
        self.sched_force_full = false;
        self.scheduler_wall_s += wall.elapsed().as_secs_f64();
        self.scheduler_invocations += 1;
        // Capacity subsystem, after the wall capture so the Fig. 20
        // scheduler-overhead metric stays a pure scheduling
        // measurement. Unservable groups retire *after* the dirt
        // clears: their removal must land in `removed_groups` for the
        // NEXT pass, or a delta pass would keep charging their penalty
        // forever. Shedding precedes the tick so the pressure signal
        // sees the post-retirement backlog.
        if !unservable.is_empty() {
            self.shed_unservable_groups(unservable);
        }
        self.capacity_tick();
        // New orders may unblock idle instances.
        let ids: Vec<InstanceId> = self
            .instances
            .iter()
            .filter(|i| self.alive[i.config.id.0 as usize])
            .map(|i| i.config.id)
            .collect();
        for id in ids {
            let t = self.now.max(self.inst(id).busy_until());
            self.wake(id, t);
        }
    }

    /// QLM / SHEPHERD: global scheduler over request groups.
    ///
    /// §Perf: steady state goes down the incremental delta path — only
    /// dirty groups are re-priced and re-inserted against the cached
    /// plan, and clean queues keep their position (the returned orders
    /// are a patch covering only changed instances). Cold caches,
    /// instance failures, and dirtiness above the configured threshold
    /// fall back to the full solve, which refreshes the cache.
    ///
    /// Returns the groups the scheduler reported unservable, for the
    /// admission controller to retire.
    fn schedule_qlm(&mut self, views: &[InstanceView]) -> Vec<GroupId> {
        let assignment = {
            let delta_try = if self.sched_force_full || !self.cfg.sched_incremental {
                None
            } else {
                let dirty: Vec<&RequestGroup> = self
                    .dirty_groups
                    .iter()
                    .filter_map(|g| self.groups.get(g))
                    .collect();
                let delta = SchedDelta {
                    dirty,
                    removed: self.removed_groups.clone(),
                    total_groups: self.groups.len(),
                };
                self.scheduler.try_schedule_delta(&delta, views, self.now)
            };
            match delta_try {
                Some(a) => a,
                None => {
                    // Full solve. Pass references — the seed cloned every
                    // group (and every member list) per invocation.
                    let group_refs: Vec<&RequestGroup> = self.groups.values().collect();
                    self.scheduler.schedule(&group_refs, views, self.now)
                }
            }
        };
        let touched: Vec<InstanceId> = assignment.orders.keys().copied().collect();
        for (id, order) in assignment.orders {
            self.vqs[id.0 as usize].set_order(order);
        }
        // Refresh warm sets for the queues that changed (§5 swapping).
        if self.cfg.policy.lso().model_swapping {
            for id in touched {
                let idx = id.0 as usize;
                let order: Vec<ModelId> = {
                    let vq = &self.vqs[idx];
                    let groups = &self.groups;
                    vq.model_order(|g| groups.get(&g))
                };
                self.instances[idx].registry_mut().set_warm_set(&order);
            }
        }
        assignment.unservable
    }

    /// Load-balancing ablation (Fig. 15's round-robin comparator, and
    /// the `-nolb` rows of Figs. 11/14): groups are dealt round-robin to
    /// compatible instances with no RWT-informed placement; per-queue
    /// ordering keeps arrival order.
    fn schedule_round_robin(&mut self, views: &[InstanceView]) {
        let mut groups: Vec<&RequestGroup> = self.groups.values().collect();
        groups.sort_by(|a, b| {
            a.deadline()
                .partial_cmp(&b.deadline())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut orders: HashMap<InstanceId, Vec<GroupId>> =
            views.iter().map(|v| (v.id, Vec::new())).collect();
        for v in views {
            if let Some(g) = v.executing {
                if self.groups.contains_key(&g) {
                    orders.get_mut(&v.id).unwrap().push(g);
                }
            }
        }
        let pinned: Vec<GroupId> = views.iter().filter_map(|v| v.executing).collect();
        let mut rr = 0usize;
        for g in groups {
            if pinned.contains(&g.id) {
                continue;
            }
            // Next compatible instance in rotation, blind to load.
            let mut placed = false;
            for k in 0..views.len() {
                let v = &views[(rr + k) % views.len()];
                if v.can_serve(g.model) {
                    orders.get_mut(&v.id).unwrap().push(g.id);
                    rr = (rr + k + 1) % views.len();
                    placed = true;
                    break;
                }
            }
            if !placed {
                if let Some(v) = views.first() {
                    orders.get_mut(&v.id).unwrap().push(g.id);
                }
            }
        }
        for (id, order) in orders {
            self.vqs[id.0 as usize].set_order(order);
        }
    }

    /// EDF baseline: deadline-sorted singleton groups, least-loaded
    /// compatible instance.
    fn schedule_edf(&mut self, views: &[InstanceView]) {
        let mut groups: Vec<&RequestGroup> = self.groups.values().collect();
        groups.sort_by(|a, b| {
            a.deadline()
                .partial_cmp(&b.deadline())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        // Load = total waiting tokens per instance.
        let mut load: HashMap<InstanceId, f64> =
            views.iter().map(|v| (v.id, 0.0)).collect();
        let mut orders: HashMap<InstanceId, Vec<GroupId>> =
            views.iter().map(|v| (v.id, Vec::new())).collect();
        // Keep executing groups pinned at the head.
        for v in views {
            if let Some(g) = v.executing {
                if self.groups.contains_key(&g) {
                    orders.get_mut(&v.id).unwrap().push(g);
                }
            }
        }
        let pinned: Vec<GroupId> = views.iter().filter_map(|v| v.executing).collect();
        for g in groups {
            if pinned.contains(&g.id) {
                continue;
            }
            let best = views
                .iter()
                .filter(|v| v.can_serve(g.model))
                .min_by(|a, b| load[&a.id].partial_cmp(&load[&b.id]).unwrap());
            if let Some(v) = best {
                orders.get_mut(&v.id).unwrap().push(g.id);
                *load.get_mut(&v.id).unwrap() += g.len() as f64;
            }
        }
        for (id, order) in orders {
            self.vqs[id.0 as usize].set_order(order);
        }
    }

    /// vLLM baseline: FCFS onto the pinned instance with least load.
    fn schedule_fcfs(&mut self, views: &[InstanceView]) {
        let mut groups: Vec<&RequestGroup> = self.groups.values().collect();
        // FCFS = earliest arrival first (group id breaks Dump-trace ties).
        groups.sort_by(|a, b| {
            a.earliest_arrival_s
                .partial_cmp(&b.earliest_arrival_s)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut load: HashMap<InstanceId, f64> =
            views.iter().map(|v| (v.id, 0.0)).collect();
        let mut orders: HashMap<InstanceId, Vec<GroupId>> =
            views.iter().map(|v| (v.id, Vec::new())).collect();
        for v in views {
            if let Some(g) = v.executing {
                if self.groups.contains_key(&g) {
                    orders.get_mut(&v.id).unwrap().push(g);
                }
            }
        }
        let pinned: Vec<GroupId> = views.iter().filter_map(|v| v.executing).collect();
        for g in groups {
            if pinned.contains(&g.id) {
                continue;
            }
            let best = views
                .iter()
                .filter(|v| self.pinned_model.get(&v.id) == Some(&g.model))
                .min_by(|a, b| load[&a.id].partial_cmp(&load[&b.id]).unwrap());
            if let Some(v) = best {
                orders.get_mut(&v.id).unwrap().push(g.id);
                *load.get_mut(&v.id).unwrap() += g.len() as f64;
            }
        }
        for (id, order) in orders {
            self.vqs[id.0 as usize].set_order(order);
        }
    }

    fn finish(self) -> RunMetrics {
        // Archive unfinished requests too (they count as violations).
        let remaining: Vec<u64> = self.queue.waiting_ids().collect();
        let mut records: Vec<RequestRecord> = self
            .queue
            .completed
            .iter()
            .map(RequestRecord::from_request)
            .collect();
        for id in remaining {
            if let Some(r) = self.queue.get(id) {
                records.push(RequestRecord::from_request(r));
            }
        }
        // Running-but-unfinished at horizon — including internally
        // preempted sequences parked in CPU swap: those are Running in
        // the broker but absent from both `waiting_ids()` and
        // `running()`, and used to vanish from the records entirely
        // (undercounting violations).
        for inst in &self.instances {
            for s in inst.running().iter().chain(inst.swapped()) {
                if let Some(r) = self.queue.get(s.req_id) {
                    records.push(RequestRecord::from_request(r));
                }
            }
        }
        // Shed requests (admission control / unservable retirement) left
        // the waiting set for good but must be recorded exactly once.
        for &id in self.queue.shed_ids() {
            if let Some(r) = self.queue.get(id) {
                records.push(RequestRecord::from_request(r));
            }
        }
        records.sort_by_key(|r| r.id);
        records.dedup_by_key(|r| r.id);
        let duration = records
            .iter()
            .filter_map(|r| r.completed_s)
            .fold(0.0_f64, f64::max)
            .max(self.now);
        // Device-seconds ledger: each instance is billed from commission
        // (cold-start completion for provisioned ones) to decommission /
        // failure / end of run. An instance that never joined — its
        // Provision event was still pending when the run ended (not
        // alive, never decommissioned) — is not billed.
        let device_seconds: f64 = (0..self.instances.len())
            .filter(|&i| self.alive[i] || self.decommissioned_at[i].is_some())
            .map(|i| {
                let start = self.commissioned_at[i].min(duration);
                let end = self.decommissioned_at[i].unwrap_or(duration).min(duration);
                (end - start).max(0.0)
            })
            .sum();
        let (scale_ups, scale_downs) = self
            .autoscaler
            .as_ref()
            .map(|a| (a.scale_ups, a.scale_downs))
            .unwrap_or((0, 0));
        RunMetrics {
            policy: self.cfg.policy.name(),
            records,
            instances: self.instances.iter().map(instance_metrics).collect(),
            duration_s: duration,
            scheduler_wall_s: self.scheduler_wall_s,
            scheduler_invocations: self.scheduler_invocations,
            device_seconds,
            scale_ups,
            scale_downs,
        }
    }
}

/// SHEPHERD's deterministic worst-case profile: μ_out := max_out, σ := 0.
fn conservative(profiles: &ProfileTable, trace: &Trace) -> ProfileTable {
    let mut out = ProfileTable::default();
    let mut keys: Vec<(ModelId, crate::workload::SloClass, bool)> = trace
        .requests
        .iter()
        .map(|r| (r.model, r.class, r.mega))
        .collect();
    keys.sort();
    keys.dedup();
    for (m, c, mg) in keys {
        let mut p = profiles.get(m, c, mg);
        p.mu_out = p.max_out;
        p.sigma_out = 0.0;
        out.insert(m, c, mg, p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fleet_a100;
    use crate::workload::WorkloadSpec;

    fn small_trace(rate: f64, n: usize) -> Trace {
        let spec = WorkloadSpec::w_a(ModelId(0), rate, n);
        Trace::generate(&spec, 42)
    }

    fn run_policy(policy: Policy, rate: f64, n: usize, fleet: u32) -> RunMetrics {
        let trace = small_trace(rate, n);
        let cfg = SimConfig::new(fleet_a100(fleet), ModelCatalog::paper(), policy);
        Simulation::new(cfg, &trace).run(&trace)
    }

    #[test]
    fn qlm_completes_all_requests_light_load() {
        let m = run_policy(Policy::qlm(), 5.0, 200, 2);
        assert_eq!(m.completed_count(), 200, "{}", m.summary());
        assert!(m.slo_attainment() > 0.9, "{}", m.summary());
    }

    #[test]
    fn vllm_completes_all_requests_light_load() {
        let m = run_policy(Policy::VllmFcfs, 5.0, 200, 2);
        assert_eq!(m.completed_count(), 200, "{}", m.summary());
    }

    #[test]
    fn edf_completes_all_requests_light_load() {
        let m = run_policy(Policy::Edf, 5.0, 200, 2);
        assert_eq!(m.completed_count(), 200, "{}", m.summary());
    }

    #[test]
    fn shepherd_completes_all_requests_light_load() {
        let m = run_policy(Policy::Shepherd, 5.0, 200, 2);
        assert_eq!(m.completed_count(), 200, "{}", m.summary());
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_policy(Policy::qlm(), 10.0, 150, 2);
        let b = run_policy(Policy::qlm(), 10.0, 150, 2);
        assert_eq!(a.completed_count(), b.completed_count());
        assert!((a.slo_attainment() - b.slo_attainment()).abs() < 1e-12);
        assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
    }

    #[test]
    fn qlm_beats_vllm_under_pressure() {
        // Overloaded single instance: QLM should prioritize interactive
        // requests and win on SLO attainment.
        let qlm = run_policy(Policy::qlm(), 40.0, 400, 1);
        let vllm = run_policy(Policy::VllmFcfs, 40.0, 400, 1);
        assert!(
            qlm.slo_attainment() >= vllm.slo_attainment(),
            "qlm {} vs vllm {}",
            qlm.summary(),
            vllm.summary()
        );
    }

    #[test]
    fn multi_model_swapping_occurs() {
        let b1 = vec![ModelId(0), ModelId(1)];
        let b2 = vec![ModelId(2), ModelId(1)];
        let spec = WorkloadSpec::w_b(b1, b2, 20.0, 300);
        let trace = Trace::generate(&spec, 7);
        let cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
        let m = Simulation::new(cfg, &trace).run(&trace);
        assert!(m.total_model_swaps() >= 2, "{}", m.summary());
        assert!(m.completed_count() > 250, "{}", m.summary());
    }

    #[test]
    fn horizon_caps_runtime() {
        let trace = small_trace(50.0, 500);
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        cfg.horizon_s = 5.0;
        let m = Simulation::new(cfg, &trace).run(&trace);
        // Not all done, but the run terminates and records everyone.
        assert_eq!(m.records.len(), 500);
    }

    #[test]
    fn instance_failure_loses_no_requests() {
        // §4 fault tolerance, end to end: kill one of two instances
        // mid-run; every request still completes on the survivor.
        let trace = small_trace(8.0, 200);
        let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
        cfg.failures = vec![(5.0, InstanceId(1))];
        let m = Simulation::new(cfg, &trace).run(&trace);
        assert_eq!(m.completed_count(), 200, "{}", m.summary());
        // The dead instance did no work after t=5.
        let healthy = run_policy(Policy::qlm(), 8.0, 200, 2);
        assert!(
            m.duration_s >= healthy.duration_s,
            "losing capacity cannot speed the run up"
        );
    }

    #[test]
    fn failover_is_deterministic() {
        let trace = small_trace(10.0, 150);
        let run = || {
            let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
            cfg.failures = vec![(3.0, InstanceId(0))];
            Simulation::new(cfg, &trace).run(&trace)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completed_count(), b.completed_count());
        assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
    }

    #[test]
    fn stale_superseded_wake_is_dropped() {
        let trace = small_trace(5.0, 3);
        let cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        let mut sim = Simulation::new(cfg, &trace);
        // Out-of-order wake requests: the earlier wake supersedes the
        // pending later one, whose heap entry cannot be cancelled.
        sim.wake(InstanceId(0), 10.0);
        sim.wake(InstanceId(0), 5.0);
        let mut honored = 0;
        while let Some(Reverse(ev)) = sim.events.pop() {
            if let EventKind::Wake(id) = ev.kind {
                if sim.take_due_wake(id, ev.t) {
                    honored += 1;
                }
            }
        }
        assert_eq!(honored, 1, "only the superseding wake may fire");
        assert_eq!(sim.wake_stats(), (1, 1), "the stale t=10 pop is dropped");
        assert_eq!(sim.wake_pending[0], None);
    }

    #[test]
    fn finish_records_internally_preempted_sequences() {
        // Horizon accounting with internal preemption active: force a
        // KV-overflow preemption so a sequence parks in the instance's
        // CPU swap (Running in the broker, absent from `waiting_ids()`
        // and `running()`), then close the books — nothing may vanish.
        let trace = small_trace(5.0, 4);
        let cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        let mut sim = Simulation::new(cfg, &trace);
        sim.instances[0].swap_model(ModelId(0), 0.0);
        let t0 = sim.instances[0].busy_until();
        let perf = sim.instances[0].perf(ModelId(0));
        let per = (perf.token_capacity / 4).saturating_sub(64) as u32;
        for i in 0..4usize {
            let id = sim.queue.submit(Request::from_trace(0, &trace.requests[i]));
            sim.queue.mark_running(id);
            let seq = RunningSeq {
                req_id: id,
                model: ModelId(0),
                prompt_tokens: per,
                target_output: 1000,
                generated: 0,
                first_token_at: None,
                arrival_s: 0.0,
            };
            sim.instances[0].try_admit(seq, t0).unwrap();
        }
        let mut now = t0;
        let mut preempted = 0;
        for _ in 0..300 {
            let out = sim.instances[0].step(now);
            now += out.dt;
            preempted += out.preempted;
            if preempted > 0 {
                break;
            }
        }
        assert!(preempted > 0, "expected KV-overflow preemption");
        assert!(sim.instances[0].swapped_len() > 0);
        let m = sim.finish();
        assert_eq!(m.records.len(), 4, "swapped sequences must be recorded");
    }

    #[test]
    fn baseline_orders_invariant_to_group_insertion_order() {
        use crate::coordinator::lso::LsoConfig;
        use crate::workload::SloClass;
        // EDF / FCFS / round-robin plans must be functions of the group
        // *set*, not of HashMap iteration order.
        let trace = small_trace(5.0, 20);
        for policy in [
            Policy::Edf,
            Policy::VllmFcfs,
            Policy::qlm_with(LsoConfig::without_load_balancing()),
        ] {
            let run_with = |rev: bool| -> Vec<Vec<GroupId>> {
                let cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), policy);
                let mut sim = Simulation::new(cfg, &trace);
                let mut ids: Vec<u64> = (0..20).collect();
                if rev {
                    ids.reverse();
                }
                for i in ids {
                    let gid = GroupId(i);
                    sim.groups.insert(
                        gid,
                        RequestGroup {
                            id: gid,
                            model: ModelId(0),
                            class: SloClass::Interactive,
                            slo_s: 20.0,
                            earliest_arrival_s: (i % 7) as f64,
                            members: VecDeque::from([i]),
                            mega: false,
                        },
                    );
                }
                let views = sim.refresh_views();
                match policy {
                    Policy::Edf => sim.schedule_edf(&views),
                    Policy::VllmFcfs => sim.schedule_fcfs(&views),
                    _ => sim.schedule_round_robin(&views),
                }
                sim.views_cache = views;
                sim.vqs
                    .iter()
                    .map(|vq| vq.groups.iter().copied().collect())
                    .collect()
            };
            assert_eq!(run_with(false), run_with(true), "{}", policy.name());
        }
    }

    #[test]
    fn open_group_index_matches_scan_semantics() {
        use crate::workload::TraceRequest;
        let trace = small_trace(5.0, 1);
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        cfg.delta = 1.0;
        cfg.avg_batch = 2; // group cap = 2
        let mut sim = Simulation::new(cfg, &trace);
        let tr = |i: usize| TraceRequest {
            arrival_s: i as f64,
            model: ModelId(0),
            class: crate::workload::SloClass::Interactive,
            slo_s: 20.0,
            input_tokens: 50,
            output_tokens: 10,
            mega: false,
        };
        for i in 0..5 {
            sim.on_arrival(&tr(i));
        }
        // Cap 2 ⇒ requests 0/1, 2/3, 4 land in three groups.
        assert_eq!(sim.groups.len(), 3);
        let g0 = sim.group_of[&0];
        assert_eq!(sim.group_of[&1], g0);
        assert_ne!(sim.group_of[&2], g0);
        // Completing a member reopens the group; the next compatible
        // arrival must join the *lowest-id* open group (the rule the
        // replaced table scan enforced).
        sim.queue.mark_running(0);
        sim.queue.complete(0, Some(1.0), 1.0);
        sim.on_request_done(0, InstanceId(0));
        sim.on_arrival(&tr(5));
        assert_eq!(sim.group_of[&5], g0, "reopened lowest-id group wins");
        // Full groups never sit in the index.
        for (key, set) in &sim.open_groups {
            for gid in set {
                assert!(sim.groups[gid].len() < 2, "{key:?} holds a full group");
            }
        }
    }

    /// Vicuna-13B W_A trace: heavy enough per token that overload forms
    /// a real *waiting* backlog (Mistral's KV capacity absorbs small
    /// bursts straight into the running batch, which never pressures
    /// the autoscaler).
    fn vicuna_trace(rate: f64, n: usize) -> Trace {
        Trace::generate(&WorkloadSpec::w_a(ModelId(1), rate, n), 42)
    }

    #[test]
    fn autoscaler_grows_fleet_under_pressure_and_completes() {
        use crate::backend::GpuKind;
        let trace = vicuna_trace(40.0, 600);
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        let mut auto = AutoscaleConfig::bounded(1, 4, GpuKind::A100);
        auto.breach_passes = 2;
        auto.cooldown_s = 5.0;
        // Short bench-scale trace: trip on a couple of seconds of
        // predicted backlog rather than the production half-SLO.
        auto.up_frac = 0.1;
        cfg.autoscale = Some(auto);
        let m = Simulation::new(cfg, &trace).run(&trace);
        assert_eq!(m.completed_count(), 600, "{}", m.summary());
        assert!(m.scale_ups >= 1, "overload must trigger provisioning");
        // The ledger bills provisioned capacity only from commission on.
        assert!(
            m.device_seconds <= 4.0 * m.duration_s + 1e-6,
            "{} vs {}",
            m.device_seconds,
            m.duration_s
        );
        // Extra capacity must not slow the run down vs the fixed fleet.
        let fixed = {
            let cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
            Simulation::new(cfg, &trace).run(&trace)
        };
        assert!(
            m.duration_s <= fixed.duration_s * 1.05,
            "auto {} vs fixed {}",
            m.duration_s,
            fixed.duration_s
        );
    }

    #[test]
    fn autoscaling_is_deterministic() {
        use crate::backend::GpuKind;
        let trace = vicuna_trace(40.0, 300);
        let run = || {
            let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
            let mut auto = AutoscaleConfig::bounded(1, 3, GpuKind::A100);
            auto.breach_passes = 2;
            auto.cooldown_s = 5.0;
            auto.up_frac = 0.1;
            cfg.autoscale = Some(auto);
            Simulation::new(cfg, &trace).run(&trace)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completed_count(), b.completed_count());
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.scale_downs, b.scale_downs);
        assert!((a.device_seconds - b.device_seconds).abs() < 1e-9);
        assert!((a.mean_ttft() - b.mean_ttft()).abs() < 1e-9);
    }

    #[test]
    fn admission_sheds_hopeless_batch_classes_only() {
        use crate::capacity::AdmissionConfig;
        // One instance under a crushing W_A overload with an aggressive
        // shed gate: batch classes are refused at the door once their
        // predicted drain blows through the gate; interactive never is.
        let trace = small_trace(60.0, 600);
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        cfg.admission = AdmissionConfig {
            enabled: true,
            shed_frac: 0.05,
            resume_frac: 0.01,
        };
        let m = Simulation::new(cfg, &trace).run(&trace);
        assert_eq!(m.records.len(), 600, "every request recorded exactly once");
        let shed = m.shed_count();
        assert!(shed > 0, "hopeless batch backlog must shed: {}", m.summary());
        assert!(
            m.records
                .iter()
                .filter(|r| r.shed)
                .all(|r| r.class != crate::workload::SloClass::Interactive),
            "interactive traffic must never be shed"
        );
        assert_eq!(
            m.completed_count() + shed,
            600,
            "shed + completed must conserve the trace"
        );
    }

    #[test]
    fn incremental_and_full_sched_paths_both_serve_everything() {
        let trace = small_trace(5.0, 200);
        let run_mode = |inc: bool| {
            let mut cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), Policy::qlm());
            cfg.sched_incremental = inc;
            Simulation::new(cfg, &trace).run(&trace)
        };
        let a = run_mode(true);
        let b = run_mode(false);
        assert_eq!(a.completed_count(), 200, "{}", a.summary());
        assert_eq!(b.completed_count(), 200, "{}", b.summary());
        assert!(a.slo_attainment() > 0.9, "{}", a.summary());
        assert!(b.slo_attainment() > 0.9, "{}", b.summary());
    }
}
