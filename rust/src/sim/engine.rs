//! The discrete-event simulation engine.
//!
//! The engine is deliberately thin — the paper's architecture is
//! layered (§5: the global scheduler produces queue orderings, LSOs are
//! "merely action actuators"), and the engine mirrors that as four
//! seams:
//!
//! * [`EventCore`](super::event) — clock, event heap, wake dedup. All
//!   time-ordering logic lives there.
//! * [`SchedulingPolicy`](crate::baselines::SchedulingPolicy) — every
//!   queue-ordering strategy (QLM's incremental global scheduler, the
//!   EDF/FCFS/round-robin/SJF baselines) behind one trait, dispatched
//!   from [`Simulation::maybe_schedule`]. A new policy is a new file in
//!   `baselines/`, not an engine edit.
//! * [`FleetController`](super::fleet_controller) — instance lifecycle
//!   (provision / drain / decommission / fail, the device-seconds
//!   ledger) and the only bridge to the capacity subsystem.
//! * A parallel view/pricing pass — per-instance view refresh fans out
//!   over a persistent [`WorkerPool`] (`SimConfig::threads`; spawned
//!   once per `Simulation` and shared with the scheduler's repricing
//!   walk), merged in index order so results are bit-identical to the
//!   serial pass.
//!
//! §Perf: the event loop is allocation-light in steady state. Per-
//! instance state lives in dense `Vec`s indexed by `InstanceId`;
//! instance views are built once and refreshed in place per scheduler
//! pass; the policy receives group *references* (never a clone of the
//! table); and scheduling is *incremental* — the engine tracks which
//! groups went dirty since the last pass (arrivals, pulls, evictions,
//! drains, failures) and hands the policy just that delta, which is
//! what lets `--scenario scale` push 100K+ queued requests through the
//! paper's Fig. 20 regime.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
// audit:allow(wall-clock): wall time feeds only the diagnostic pass-duration
// histogram, never simulated time or any scheduling decision.
use std::time::Instant as WallInstant;

use crate::backend::{
    Instance, InstanceConfig, InstanceId, ModelCatalog, ModelId, PerfModel, RunningSeq,
};
use crate::baselines::{build_policy, Policy, PolicyCtx, SchedulingPolicy};
use crate::capacity::{
    AdmissionConfig, AdmissionController, AutoscaleConfig, Autoscaler, ScaleDecision,
};
use crate::coordinator::agent::{InstanceObservation, QlmAgent};
use crate::coordinator::lso::LsoAction;
use crate::coordinator::request::{Request, RequestState};
use crate::coordinator::request_group::{GroupId, Grouper, RequestGroup};
use crate::coordinator::rwt::{ProfileTable, RwtEstimator};
use crate::coordinator::scheduler::{InstanceView, SchedulerConfig, SolverKind};
use crate::coordinator::virtual_queue::VirtualQueue;
use crate::coordinator::GlobalQueue;
use crate::metrics::{collect_records, instance_metrics, CompactTally, RunMetrics};
use crate::obs::{InstanceSample, ObsConfig, ObsReport, ObsState, TelemetrySample, TraceEventKind};
use crate::sim::event::{EventCore, EventKind};
use crate::sim::fleet_controller::{static_pinning, FleetController};
use crate::sim::profiler::{conservative_profiles, profile_spec, ThetaCache};
use crate::sim::views;
use crate::util::WorkerPool;
use crate::workload::{ArrivalStream, SloClass, Trace, WorkloadSpec};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub fleet: Vec<InstanceConfig>,
    pub catalog: ModelCatalog,
    pub policy: Policy,
    pub seed: u64,
    /// δ — request-group size as a multiple of avg batch size (§8.3).
    pub delta: f64,
    /// Average batch size used for the group-size cap.
    pub avg_batch: u32,
    /// Hard stop (simulated seconds).
    pub horizon_s: f64,
    /// Min simulated gap between global-scheduler invocations.
    pub sched_interval_s: f64,
    /// Injected instance failures (§4 Fault Tolerance): at simulated
    /// time `t`, the instance is lost — its running batch and parked KV
    /// vanish, and every affected request reverts to Waiting in the
    /// global queue. Drives the `failover` CLI scenario.
    pub failures: Vec<(f64, InstanceId)>,
    /// Allow the global scheduler's incremental delta path (on by
    /// default). Off forces a full re-solve every pass — the Fig. 20
    /// overhead baseline and the `sched_incremental` bench comparator.
    pub sched_incremental: bool,
    /// Worker threads for the parallel view/pricing pass (`qlm sim
    /// --threads N`). The per-instance view refresh and the scheduler's
    /// per-queue repricing walk fan out over one persistent
    /// [`WorkerPool`] (spawned once per `Simulation`, workers parked
    /// between passes) with an index-ordered merge, so any thread count
    /// produces the same `RunMetrics` bit for bit. 1 (default) = fully
    /// serial (no workers spawned).
    pub threads: usize,
    /// Runtime autoscaling (capacity subsystem): provision instances
    /// under sustained predicted violations, drain them when calm.
    /// `fleet` is the starting fleet; the autoscaler grows/shrinks it
    /// between `min_instances` and `max_instances`. Only meaningful for
    /// group-based policies (QLM / SHEPHERD).
    pub autoscale: Option<AutoscaleConfig>,
    /// Submit-time admission control (shed batch classes when even the
    /// maximal fleet cannot meet their SLO). Disabled by default.
    pub admission: AdmissionConfig,
    /// Per-iteration prefill chunk budget (tokens) applied to every
    /// instance. `None` = whole-prompt prefill, except under the
    /// `chunked` policy which defaults to its base budget.
    pub chunk_tokens: Option<u32>,
    /// Decode slice length (tokens): slice boundaries are the points
    /// the engine may migrate a request at. `None` = no slicing, except
    /// under the `chunked` policy which defaults to its slice length.
    pub slice_tokens: Option<u32>,
    /// Observability: flight recorder + telemetry sampler + RWT ledger.
    /// Default off; when off the engine allocates no observer state and
    /// every hook is a single skipped `if let`.
    pub obs: ObsConfig,
    /// Compact records (gigascale benches): acked requests are dropped
    /// from the broker instead of archived, and completions are folded
    /// into a [`CompactTally`] — resident memory stays O(in-flight) at
    /// any request count. Per-request records then cover only unserved
    /// and shed requests; aggregates live in `RunMetrics::compact`.
    pub compact_records: bool,
}

impl SimConfig {
    pub fn new(fleet: Vec<InstanceConfig>, catalog: ModelCatalog, policy: Policy) -> Self {
        SimConfig {
            fleet,
            catalog,
            policy,
            seed: 0,
            delta: 4.0,
            avg_batch: 64,
            horizon_s: 7200.0,
            sched_interval_s: 0.25,
            failures: Vec::new(),
            sched_incremental: true,
            threads: 1,
            autoscale: None,
            admission: AdmissionConfig::default(),
            chunk_tokens: None,
            slice_tokens: None,
            obs: ObsConfig::default(),
            compact_records: false,
        }
    }

    /// Effective chunk budget: explicit setting, else the chunked
    /// policy's default, else off.
    pub fn effective_chunk_tokens(&self) -> Option<u32> {
        self.chunk_tokens.or(match self.policy {
            Policy::Chunked => Some(crate::baselines::chunked::DEFAULT_CHUNK_TOKENS),
            _ => None,
        })
    }

    /// Effective slice length: explicit setting, else the chunked
    /// policy's default, else off.
    pub fn effective_slice_tokens(&self) -> Option<u32> {
        self.slice_tokens.or(match self.policy {
            Policy::Chunked => Some(crate::baselines::chunked::DEFAULT_SLICE_TOKENS),
            _ => None,
        })
    }
}

/// Waiting (or evicted) members of a group, FCFS.
fn waiting_members(
    groups: &BTreeMap<GroupId, RequestGroup>,
    queue: &GlobalQueue,
    gid: GroupId,
) -> Vec<u64> {
    let Some(g) = groups.get(&gid) else {
        return Vec::new();
    };
    g.members
        .iter()
        .copied()
        .filter(|id| {
            queue
                .get(*id)
                .map(|r| matches!(r.state, RequestState::Waiting | RequestState::Evicted))
                .unwrap_or(false)
        })
        .collect()
}

/// The simulator.
pub struct Simulation {
    cfg: SimConfig,
    /// Clock + timer-wheel event queue + wake dedup (the time-ordering
    /// seam).
    clock: EventCore,
    /// Instances + lifecycle + the capacity bridge (the fleet seam).
    fleet: FleetController,
    /// The queue-ordering strategy (the policy seam).
    policy: Box<dyn SchedulingPolicy>,
    /// Dense per-instance scheduling state, indexed by `InstanceId.0`.
    vqs: Vec<VirtualQueue>,
    agents: Vec<QlmAgent>,
    queue: GlobalQueue,
    groups: BTreeMap<GroupId, RequestGroup>,
    group_of: BTreeMap<u64, GroupId>,
    grouper: Grouper,
    /// Workload moments (§6 Offline Profiling) — conservative for
    /// SHEPHERD. Shared by observation sizing and pressure pricing; the
    /// policy's estimator holds its own copy.
    profiles: ProfileTable,
    /// Static model pinning for no-swap policies (vLLM baseline).
    pinned_model: BTreeMap<InstanceId, ModelId>,
    needs_schedule: bool,
    last_schedule: f64,
    scheduler_wall_s: f64,
    scheduler_invocations: u64,
    /// Incremental-scheduler dirty tracking: groups whose membership,
    /// deadline anchor, or member states changed since the last pass.
    /// `BTreeSet` for deterministic iteration order.
    dirty_groups: BTreeSet<GroupId>,
    /// Groups that drained (all members complete) since the last pass.
    removed_groups: Vec<GroupId>,
    /// Force the next pass down the full-solve path (view-set changes:
    /// failures, provisions, drains make any cached plan unusable).
    sched_force_full: bool,
    /// Hardware-profiled Θ per (gpu, model) — §6 Offline Profiling.
    thetas: ThetaCache,
    /// Scheduler views, built once and refreshed in place per pass
    /// (dead instances are dropped on failure).
    views_cache: Vec<InstanceView>,
    /// The persistent worker pool behind every parallel pass — spawned
    /// once here, shared with the policy's global scheduler (one set of
    /// parked workers serves the view refresh *and* the repricing walk).
    pool: Arc<WorkerPool>,
    /// Streamed arrivals for [`Self::run_streaming`] — pulled lazily
    /// and merged against the event clock, so a streamed run never
    /// materializes the trace. `None` for materialized runs.
    stream: Option<Box<ArrivalStream>>,
    /// Total requests a streamed run will see (`spec.total_requests()`)
    /// — the termination count `run` reads off `trace.len()`.
    stream_total: usize,
    /// Completion aggregates for compact-records mode (folded before
    /// each ack, since the ack drops the request).
    tally: CompactTally,
    /// Observability state (flight recorder + telemetry + RWT ledger).
    /// `None` when disabled — the hooks are then a skipped `if let`
    /// each, so the hot path pays nothing. The observer records; it
    /// never feeds back into scheduling decisions.
    obs: Option<Box<ObsState>>,
    /// Reused scratch for the per-pass collections in `maybe_schedule`
    /// (dirty-group deadline re-anchoring) — cleared each pass, freed
    /// never, so the steady-state pass allocates nothing
    /// (`cargo bench -- hot_alloc` counts this).
    scratch_earliest: Vec<(GroupId, f64)>,
    /// Reused scratch for post-pass wake fan-outs (`maybe_schedule`,
    /// `wake_idle`).
    scratch_wake: Vec<(InstanceId, f64)>,
    /// Reused scratch for the instances touched by a policy patch.
    scratch_touched: Vec<InstanceId>,
}

impl Simulation {
    pub fn new(cfg: SimConfig, trace: &Trace) -> Self {
        Self::new_inner(cfg, trace, false)
    }

    /// Run the simulation on the retained `BinaryHeap` event queue
    /// instead of the timer wheel — the golden suite's wheel ≡ heap
    /// equivalence runs drive whole scenarios through both.
    #[doc(hidden)]
    pub fn new_with_heap_clock(cfg: SimConfig, trace: &Trace) -> Self {
        Self::new_inner(cfg, trace, true)
    }

    fn new_inner(cfg: SimConfig, trace: &Trace, heap_clock: bool) -> Self {
        // Workload profiling (§6, Offline Profiling): moments from the
        // request history dataset — we use the trace itself as history.
        let profiles = ProfileTable::from_trace(trace);
        let mut counts: BTreeMap<ModelId, usize> = BTreeMap::new();
        for r in &trace.requests {
            *counts.entry(r.model).or_insert(0) += 1;
        }
        let mut sim = Self::assemble(cfg, profiles, &counts, heap_clock);
        // Arrivals strictly before failures: arrival events take the
        // low seqs, so at equal timestamps an arrival fires first —
        // the ordering the streamed merge reproduces.
        for (i, r) in trace.requests.iter().enumerate() {
            sim.clock.push(r.arrival_s, EventKind::Arrival(i));
        }
        sim.push_failures();
        sim
    }

    /// Streaming construction: workload moments and pinning counts come
    /// from seeded [`ArrivalStream`] replays (bit-identical to the
    /// trace-derived ones), and the arrival stream itself is held for
    /// [`Self::run_streaming`] — nothing O(total-requests) is ever
    /// materialized except the broker's 8-byte-per-id route table.
    /// `trace_seed` must be the seed the materialized run would pass to
    /// `Trace::generate`.
    pub fn new_streaming(cfg: SimConfig, spec: &WorkloadSpec, trace_seed: u64) -> Self {
        let (profiles, counts) = profile_spec(spec, trace_seed);
        let mut sim = Self::assemble(cfg, profiles, &counts, false);
        sim.push_failures();
        sim.stream = Some(Box::new(ArrivalStream::new(spec, trace_seed)));
        sim.stream_total = spec.total_requests();
        sim
    }

    fn push_failures(&mut self) {
        let failures = self.cfg.failures.clone();
        for (t, inst) in failures {
            self.clock.push(t, EventKind::Fail(inst));
        }
    }

    /// Everything both constructors share: fleet, policy, pinning,
    /// grouper, controller. Pushes no events — the callers own the
    /// arrival/failure seq ordering.
    fn assemble(
        cfg: SimConfig,
        mut profiles: ProfileTable,
        model_counts: &BTreeMap<ModelId, usize>,
        heap_clock: bool,
    ) -> Self {
        if cfg.policy.conservative_estimator() {
            // SHEPHERD-style deterministic worst-case estimates: every
            // request is assumed to run to the max output length.
            profiles = conservative_profiles(&profiles);
        }
        let estimator = RwtEstimator::new(profiles.clone());
        let solver = match cfg.policy {
            Policy::Qlm { solver, .. } => solver,
            _ => SolverKind::Greedy,
        };
        let sched_cfg = SchedulerConfig {
            solver,
            incremental: cfg.sched_incremental,
            threads: cfg.threads,
            ..Default::default()
        };
        // One pool per simulation: the view refresh and the scheduler's
        // repricing walk share its parked workers for the whole run.
        let pool = Arc::new(WorkerPool::new(cfg.threads));
        let chunk_tokens = cfg.effective_chunk_tokens();
        let slice_tokens = cfg.effective_slice_tokens();
        let policy = build_policy(
            cfg.policy,
            sched_cfg,
            estimator,
            Arc::clone(&pool),
            chunk_tokens,
        );
        let mut instances: Vec<Instance> = cfg
            .fleet
            .iter()
            .map(|c| {
                let mut inst = Instance::new(c.clone(), cfg.catalog.clone());
                inst.set_token_knobs(chunk_tokens, slice_tokens);
                inst.set_trace_chunks(cfg.obs.trace);
                inst
            })
            .collect();
        // Dense indexing requires the fleet builders' sequential ids.
        for (idx, inst) in instances.iter().enumerate() {
            debug_assert_eq!(inst.config.id.0 as usize, idx, "fleet ids must be dense");
        }
        let pinned_model = static_pinning(&mut instances, &cfg.catalog, &cfg.policy, model_counts);
        let vqs = instances
            .iter()
            .map(|i| VirtualQueue::new(i.config.id))
            .collect();
        let lso = cfg.policy.lso();
        let agents = instances
            .iter()
            .map(|i| QlmAgent::new(i.config.id, lso))
            .collect();
        let grouper = Grouper::new(cfg.delta, cfg.avg_batch, cfg.seed ^ 0x9E37);
        let n_instances = instances.len();
        // Autoscaling needs the group/virtual-queue machinery; baseline
        // per-request policies keep their fixed fleet.
        let autoscaler = cfg
            .autoscale
            .filter(|_| cfg.policy.uses_groups())
            .map(Autoscaler::new);
        let admission = AdmissionController::new(cfg.admission);
        let fleet = FleetController::new(instances, cfg.catalog.clone(), autoscaler, admission);
        let mut sim = Simulation {
            clock: if heap_clock {
                EventCore::new_heap_baseline(n_instances)
            } else {
                EventCore::new(n_instances)
            },
            fleet,
            policy,
            vqs,
            agents,
            queue: {
                let mut q = GlobalQueue::new();
                q.set_compact(cfg.compact_records);
                q
            },
            groups: BTreeMap::new(),
            group_of: BTreeMap::new(),
            grouper,
            profiles,
            pinned_model,
            needs_schedule: false,
            last_schedule: -1e9,
            scheduler_wall_s: 0.0,
            scheduler_invocations: 0,
            dirty_groups: BTreeSet::new(),
            removed_groups: Vec::new(),
            sched_force_full: false,
            thetas: ThetaCache::new(),
            views_cache: Vec::new(),
            pool,
            stream: None,
            stream_total: 0,
            tally: CompactTally::default(),
            obs: cfg.obs.enabled().then(|| Box::new(ObsState::new(&cfg.obs))),
            scratch_earliest: Vec::new(),
            scratch_wake: Vec::new(),
            scratch_touched: Vec::new(),
            cfg,
        };
        sim.build_views();
        sim
    }

    /// Request a wake for a live instance (EventCore owns the dedup).
    fn wake(&mut self, id: InstanceId, t: f64) {
        if !self.fleet.alive(id) {
            return;
        }
        self.clock.wake(id, t);
    }

    /// (honored, stale-dropped) wake pops — observability for the
    /// at-most-one-pending-Wake invariant.
    pub fn wake_stats(&self) -> (u64, u64) {
        self.clock.wake_stats()
    }

    /// Build one instance's scheduler view from profiled perf.
    fn build_view_for(&mut self, idx: usize) -> InstanceView {
        views::build_view(
            idx,
            self.fleet.instances(),
            &self.cfg.catalog,
            &self.pinned_model,
            &mut self.thetas,
        )
    }

    /// Build the scheduler views once at startup.
    fn build_views(&mut self) {
        let views: Vec<InstanceView> = (0..self.fleet.instance_count())
            .map(|idx| self.build_view_for(idx))
            .collect();
        self.views_cache = views;
    }

    /// Refresh the cached views in place for one scheduler pass (the
    /// parallel fan-out lives in [`views::refresh_all`]). Returns the
    /// views by value (callers put them back via `views_cache`) so the
    /// policy can borrow `self` fields alongside them.
    fn refresh_views(&mut self) -> Vec<InstanceView> {
        let mut views = std::mem::take(&mut self.views_cache);
        let fleet = &self.fleet;
        views.retain(|v| fleet.alive(v.id));
        views::refresh_all(&mut views, fleet.instances(), &self.group_of, &self.pool);
        views
    }

    /// Bench/test hook for the parallel view-refresh pass: run one
    /// refresh and fold the result into an order-stable digest.
    #[doc(hidden)]
    pub fn refresh_views_for_bench(&mut self) -> u64 {
        let views = self.refresh_views();
        let digest = views::digest(&views);
        self.views_cache = views;
        digest
    }

    /// Bench hook for the pool-vs-scoped comparison: the same refresh
    /// through the scoped-spawn baseline (`util::par_chunks_mut`), so
    /// `cargo bench -- par_views` can gate the persistent pool against
    /// the spawn-per-pass implementation it replaced on identical work.
    #[doc(hidden)]
    pub fn refresh_views_scoped_for_bench(&mut self) -> u64 {
        let mut views = std::mem::take(&mut self.views_cache);
        let fleet = &self.fleet;
        views.retain(|v| fleet.alive(v.id));
        views::refresh_all_scoped(
            &mut views,
            fleet.instances(),
            &self.group_of,
            self.cfg.threads,
        );
        let digest = views::digest(&views);
        self.views_cache = views;
        digest
    }

    /// The engine's persistent worker pool (observability: the pool
    /// reuse tests assert one spawn serves the whole run).
    #[doc(hidden)]
    pub fn worker_pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Run to completion (all requests served) or the horizon.
    pub fn run(self, trace: &Trace) -> RunMetrics {
        self.run_with_obs(trace).0
    }

    /// [`run`](Self::run), also returning the observability report when
    /// the config enabled tracing or telemetry (`None` otherwise). The
    /// observer only records — metrics are bit-identical either way.
    pub fn run_with_obs(mut self, trace: &Trace) -> (RunMetrics, Option<ObsReport>) {
        let total = trace.len();
        while let Some(ev) = self.clock.pop() {
            if ev.t > self.cfg.horizon_s {
                // Horizon hit: still register any not-yet-arrived requests
                // so metrics count them (as violations if unserved).
                if let EventKind::Arrival(i) = ev.kind {
                    let req = Request::from_trace(0, &trace.requests[i]);
                    self.queue.submit(req);
                }
                while let Some(e2) = self.clock.pop() {
                    if let EventKind::Arrival(i) = e2.kind {
                        let req = Request::from_trace(0, &trace.requests[i]);
                        self.queue.submit(req);
                    }
                }
                break;
            }
            self.sample_telemetry_until(ev.t);
            self.clock.now = ev.t;
            match ev.kind {
                EventKind::Arrival(i) => self.on_arrival(&trace.requests[i]),
                EventKind::Wake(id) => {
                    // A stale (superseded) pop fires no on_wake, but
                    // still falls through to maybe_schedule below — an
                    // interval-deferred pending schedule must not be
                    // dropped along with the event.
                    if self.clock.take_due_wake(id, ev.t) {
                        self.on_wake(id);
                    }
                }
                EventKind::Fail(id) => self.on_fail(id),
                EventKind::Provision(id) => self.on_provision(id),
            }
            self.maybe_schedule();
            if self.queue.len_completed() + self.queue.len_shed() == total {
                break;
            }
        }
        let obs = self.obs.take();
        let metrics = self.finish();
        (metrics, obs.map(|o| o.into_report()))
    }

    /// Run a [`Self::new_streaming`] simulation to completion. Arrivals
    /// are pulled lazily from the seeded stream and merged against the
    /// event clock, so memory stays O(in-flight): the trace is never
    /// materialized. Bit-identical to `run` on the generated trace —
    /// materialized arrivals occupy seqs `0..N-1` (pushed before
    /// failures and all runtime wakes), so at equal timestamps the
    /// arrival fires first; the `ta <= te` take rule below reproduces
    /// exactly that order.
    pub fn run_streaming(self) -> RunMetrics {
        self.run_streaming_with_obs().0
    }

    /// [`run_streaming`](Self::run_streaming) with the observability
    /// report (see [`run_with_obs`](Self::run_with_obs)).
    pub fn run_streaming_with_obs(mut self) -> (RunMetrics, Option<ObsReport>) {
        let total = self.stream_total;
        let mut stream = self
            .stream
            .take()
            .expect("run_streaming requires new_streaming construction");
        loop {
            let ta = stream.peek_t();
            let te = self.clock.peek_t();
            let take_arrival = match (ta, te) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(a), Some(e)) => a <= e,
            };
            if take_arrival {
                let tr = stream.next().expect("peeked arrival must exist");
                if tr.arrival_s > self.cfg.horizon_s {
                    // Horizon hit on an arrival: register it and every
                    // later one so metrics count them (mirrors the
                    // materialized drain — remaining events drop).
                    self.queue.submit(Request::from_trace(0, &tr));
                    for late in stream.by_ref() {
                        self.queue.submit(Request::from_trace(0, &late));
                    }
                    break;
                }
                self.sample_telemetry_until(tr.arrival_s);
                self.clock.now = tr.arrival_s;
                self.on_arrival(&tr);
            } else {
                let ev = self.clock.pop().expect("peeked event must exist");
                if ev.t > self.cfg.horizon_s {
                    // Horizon hit on a runtime event: in the materialized
                    // drain every remaining arrival (all later than this
                    // event) still gets submitted in trace order.
                    for late in stream.by_ref() {
                        self.queue.submit(Request::from_trace(0, &late));
                    }
                    break;
                }
                self.sample_telemetry_until(ev.t);
                self.clock.now = ev.t;
                match ev.kind {
                    EventKind::Arrival(_) => {
                        unreachable!("streamed runs push no Arrival events")
                    }
                    EventKind::Wake(id) => {
                        if self.clock.take_due_wake(id, ev.t) {
                            self.on_wake(id);
                        }
                    }
                    EventKind::Fail(id) => self.on_fail(id),
                    EventKind::Provision(id) => self.on_provision(id),
                }
            }
            self.maybe_schedule();
            if self.queue.len_completed() + self.queue.len_shed() == total {
                break;
            }
        }
        let obs = self.obs.take();
        let metrics = self.finish();
        (metrics, obs.map(|o| o.into_report()))
    }

    /// Telemetry sampler: emit one fleet snapshot per elapsed cadence
    /// tick in `(clock.now, t]`. Driven from the single-threaded event
    /// loop *before* the clock advances, so samples land at the same
    /// simulated instants regardless of `--threads` and re-runs.
    fn sample_telemetry_until(&mut self, t: f64) {
        let Some(obs) = self.obs.as_deref_mut() else {
            return;
        };
        let Some(tel) = obs.telemetry.as_mut() else {
            return;
        };
        while tel.next_s <= t {
            let ts = tel.next_s;
            tel.next_s += tel.every_s;
            let (active, warming, draining) = self.fleet.occupancy_counts();
            let (scale_ups, scale_downs) = self.fleet.scale_stats();
            let (wakes_honored, wakes_stale) = self.clock.wake_stats();
            let instances = self
                .fleet
                .alive_ids()
                .into_iter()
                .map(|id| {
                    let inst = self.fleet.inst(id);
                    InstanceSample {
                        id: id.0,
                        model: inst.active_model().map(|m| m.0),
                        running: inst.running_len(),
                        swapped: inst.swapped_len(),
                        kv: inst.kv_utilization(),
                    }
                })
                .collect();
            let shedding = SloClass::ALL
                .iter()
                .copied()
                .filter(|&c| self.fleet.admission.should_shed(c))
                .collect();
            tel.record(&TelemetrySample {
                t: ts,
                waiting: self.fleet.waiting_by_class(),
                instances,
                active,
                warming,
                draining,
                scale_ups,
                scale_downs,
                shedding,
                sched: obs.sched,
                wakes_honored,
                wakes_stale,
            });
        }
    }

    /// Adjust the per-(class, model) waiting counter for request `rid`.
    /// The request must still be resident in the broker.
    fn note_waiting(&mut self, rid: u64, delta: i64) {
        if let Some(r) = self.queue.get(rid) {
            self.fleet.note_waiting((r.class, r.model, r.mega), delta);
        }
    }

    fn on_arrival(&mut self, tr: &crate::workload::TraceRequest) {
        let req = Request::from_trace(0, tr);
        let id = self.queue.submit(req);
        // Admission control: a hopeless batch class is refused at the
        // door — recorded as shed, never grouped, never scheduled — so
        // its backlog cannot poison the penalty signal for requests
        // that still have a chance.
        if self.fleet.admission.should_shed(tr.class) {
            self.queue.shed(id);
            self.fleet.admission.note_shed_submit();
            if let Some(obs) = self.obs.as_deref_mut() {
                obs.record(self.clock.now, id, TraceEventKind::Shed);
            }
            return;
        }
        // audit:allow(hot-path-panic): `id` was returned by `submit` just above.
        let req = self.queue.get(id).unwrap().clone();
        // Flight recorder: stamp the submit, with the RWT the estimator
        // would quote *now* (before this request joins the waiting
        // counters) — the ledger joins it against the actual wait at
        // first pull.
        if let Some(obs) = self.obs.as_deref_mut() {
            let predicted = if obs.trace {
                crate::obs::predict_wait(
                    &self.views_cache,
                    &self.profiles,
                    req.model,
                    req.class,
                    req.mega,
                    self.fleet.waiting_for_model(req.model),
                )
            } else {
                None
            };
            if let Some(p) = predicted {
                obs.ledger.note_predicted(id, req.class, p);
            }
            obs.record(
                self.clock.now,
                id,
                TraceEventKind::Submitted {
                    model: req.model,
                    class: req.class,
                    mega: req.mega,
                    predicted_wait_s: predicted,
                },
            );
        }
        self.note_waiting(id, 1);
        // Group formation (§4).
        let gid = if self.cfg.policy.uses_groups() {
            // §Perf: classify in place against the open-group index
            // (cloning every live group per arrival was
            // O(groups × members); scanning the live table was
            // O(groups) — both cap queue scale).
            self.classify_in_place(&req)
        } else {
            // Per-request singleton groups (EDF / SJF / vLLM): id =
            // request id, which preserves FCFS order across groups.
            let gid = GroupId(id);
            self.groups.insert(
                gid,
                RequestGroup {
                    id: gid,
                    model: req.model,
                    class: req.class,
                    slo: req.slo,
                    earliest_arrival_s: req.arrival_s,
                    members: vec![id],
                    mega: req.mega,
                },
            );
            gid
        };
        self.group_of.insert(id, gid);
        self.dirty_groups.insert(gid);
        self.needs_schedule = true;
        self.wake_idle();
    }

    /// Incremental request-group classification (§4, Handling New
    /// Incoming Requests) through the open-group index: O(1) per
    /// arrival. The index holds, per (model, class, mega), exactly the
    /// live groups with spare capacity; taking the `BTreeSet` minimum
    /// reproduces the lowest-id-wins rule of the table scan this
    /// replaces, so placement stays independent of hash-map iteration
    /// order — and no longer scales with the live group count (the
    /// autoscale scenario's churn regime, ROADMAP open item).
    fn classify_in_place(&mut self, req: &Request) -> GroupId {
        let cap = self.grouper.max_group_size();
        let key = (req.model, req.class, req.mega);
        if let Some(gid) = self.queue.open_group_first(key.0, key.1, key.2) {
            // audit:allow(hot-path-panic): open-group index entries are removed
            // before their group leaves the table.
            let g = self.groups.get_mut(&gid).expect("open-group index is live");
            debug_assert!(g.len() < cap, "index must only hold open groups");
            g.members.push(req.id);
            g.slo = g.slo.min(req.slo);
            g.earliest_arrival_s = g.earliest_arrival_s.min(req.arrival_s);
            if g.len() >= cap {
                self.queue.open_group_remove(key.0, key.1, key.2, gid);
            }
            return gid;
        }
        let mut list = Vec::new();
        let gid = self.grouper.classify(req, &mut list);
        // audit:allow(hot-path-panic): `classify` pushed exactly one group above.
        let g = list.pop().unwrap();
        let open = g.len() < cap;
        self.groups.insert(gid, g);
        if open {
            self.queue.open_group_insert(key.0, key.1, key.2, gid);
        }
        gid
    }

    fn wake_idle(&mut self) {
        let now = self.clock.now;
        let mut ids = std::mem::take(&mut self.scratch_wake);
        ids.clear();
        ids.extend(
            self.fleet
                .instances()
                .iter()
                .filter(|i| self.fleet.alive(i.config.id) && i.is_idle())
                .map(|i| (i.config.id, now.max(i.busy_until()))),
        );
        for &(id, t) in &ids {
            self.wake(id, t);
        }
        self.scratch_wake = ids;
    }

    fn observation(&self, id: InstanceId) -> InstanceObservation {
        let inst = self.fleet.inst(id);
        let running = inst
            .running()
            .iter()
            .filter_map(|s| self.group_of.get(&s.req_id).map(|&g| (s.req_id, g)))
            .collect();
        // vLLM semantics: internally preempted (swapped) sequences have
        // strict priority over new admissions — while any exist, the
        // instance is considered full. Without this gate, fresh prompts
        // steal the blocks preempted sequences need and TTFT collapses to
        // prefill time while per-request progress starves.
        let spare = if inst.swapped_len() > 0 {
            0
        } else {
            inst.spare_tokens()
        };
        InstanceObservation {
            id,
            active_model: inst.active_model(),
            swapping: inst.is_swapping(self.clock.now),
            running,
            spare_capacity_tokens: spare,
            batch_slots_free: inst.batch_slots_free(),
        }
    }

    fn on_wake(&mut self, id: InstanceId) {
        let idx = id.0 as usize;
        if !self.fleet.alive(id) {
            return;
        }
        // Draining (scale-down): once the remaining batch completes, the
        // instance leaves the fleet. Until then it keeps stepping but
        // admits nothing new.
        if self.fleet.is_draining(id) && self.fleet.inst(id).is_idle() {
            self.decommission(id);
            return;
        }
        // Mid-swap: try again when the swap completes.
        let busy_until = self.fleet.inst(id).busy_until();
        if self.clock.now < busy_until {
            self.wake(id, busy_until);
            return;
        }
        // Mid-iteration: a decode step is atomic GPU work; defer.
        let free_at = self.clock.next_free(id);
        if self.clock.now < free_at - 1e-12 {
            self.wake(id, free_at);
            return;
        }

        // SHEPHERD fixed batches: only admit when the batch fully drained.
        let fixed = self.cfg.policy.fixed_batches();
        let can_admit =
            !self.fleet.is_draining(id) && (!fixed || self.fleet.inst(id).running_len() == 0);

        if can_admit {
            // §Perf: the agent reads the live virtual queue and group
            // table by reference — the seed cloned both on every wake.
            let vq = &self.vqs[idx];
            let obs = self.observation(id);
            let agent = &self.agents[idx];
            let queue_ref = &self.queue;
            let groups_ref = &self.groups;
            let profiles_ref = &self.profiles;
            let actions = agent.decide(
                vq,
                groups_ref,
                |g| waiting_members(groups_ref, queue_ref, g),
                &obs,
                |rid| {
                    queue_ref
                        .get(rid)
                        .map(|r| {
                            if fixed {
                                // SHEPHERD-style fixed batches must be
                                // sized for the deterministic worst case:
                                // prompt + max output tokens (this is the
                                // under-utilization Fig. 1 critiques).
                                let prof = profiles_ref.get(r.model, r.class, r.mega);
                                r.input_tokens as u64 + prof.max_out as u64
                            } else {
                                (r.input_tokens + r.generated) as u64
                            }
                        })
                        .unwrap_or(0)
                },
            );
            self.apply_actions(id, actions);
        }

        // One continuous-batching iteration.
        let now = self.clock.now;
        let out = self.fleet.inst_mut(id).step(now);
        for (rid, t) in &out.first_tokens {
            self.queue.record_first_token(*rid, *t);
            if let Some(obs) = self.obs.as_deref_mut() {
                if let Some(r) = self.queue.get(*rid) {
                    obs.record(
                        *t,
                        *rid,
                        TraceEventKind::FirstToken { inst: id, ttft_s: *t - r.arrival_s },
                    );
                }
            }
        }
        // Prefill chunk events only exist when tracing (the instance
        // collects them behind its own `trace_chunks` flag).
        if let Some(obs) = self.obs.as_deref_mut() {
            for &(rid, tokens) in &out.prefill_chunks {
                obs.record(now, rid, TraceEventKind::PrefillChunk { inst: id, tokens });
            }
        }
        let t_done = self.clock.now + out.dt;
        for seq in out.completed {
            // Compact runs archive no per-request records, so the
            // aggregate SLO numerators fold here, while the request is
            // still resident — the only moment both its arrival stamp
            // and its outcome coexist.
            if self.queue.is_compact() {
                if let Some(r) = self.queue.get(seq.req_id) {
                    self.tally.note(
                        r.arrival_s,
                        r.first_token_s.or(seq.first_token_at),
                        r.slo.ttft_s,
                        seq.generated,
                    );
                }
            }
            self.queue
                .complete(seq.req_id, seq.first_token_at, t_done, seq.generated);
            self.on_request_done(seq.req_id, id);
            if let Some(obs) = self.obs.as_deref_mut() {
                obs.record(
                    t_done,
                    seq.req_id,
                    TraceEventKind::Completed {
                        inst: id,
                        generated: seq.generated,
                        e2e_s: t_done - seq.arrival_s,
                    },
                );
            }
        }
        // Slice boundaries are the migration points: a sequence whose
        // decode slice just expired may be displaced — through the same
        // evict/restore KV path the eviction LSO uses — when queued work
        // is starved for admission space on this instance.
        if !out.slice_expired.is_empty() {
            if let Some(obs) = self.obs.as_deref_mut() {
                for &rid in &out.slice_expired {
                    let generated = self
                        .fleet
                        .inst(id)
                        .running()
                        .iter()
                        .find(|s| s.req_id == rid)
                        .map(|s| s.generated)
                        .unwrap_or(0);
                    obs.record(t_done, rid, TraceEventKind::DecodeSlice { inst: id, generated });
                }
            }
            self.migrate_expired_slices(id, &out.slice_expired);
        }
        if out.dt > 0.0 {
            self.clock.set_next_free(id, t_done);
            self.wake(id, t_done);
        } else if !self.fleet.inst(id).is_idle() {
            // Has swapped-out work but no progress possible; re-check soon.
            self.wake(id, self.clock.now + 0.05);
        }
    }

    fn apply_actions(&mut self, id: InstanceId, actions: Vec<LsoAction>) {
        for a in actions {
            match a {
                LsoAction::SwapModel { model, .. } => {
                    let now = self.clock.now;
                    let (ready, displaced) = self.fleet.inst_mut(id).swap_model(model, now);
                    for seq in displaced {
                        self.queue.requeue_evicted(seq.req_id, seq.generated, id);
                        self.note_waiting(seq.req_id, 1);
                        if let Some(&g) = self.group_of.get(&seq.req_id) {
                            self.dirty_groups.insert(g);
                        }
                        if let Some(obs) = self.obs.as_deref_mut() {
                            obs.record(now, seq.req_id, TraceEventKind::Swapped { inst: id, model });
                        }
                    }
                    // Warm-set update from the vq's model order (§5).
                    let order: Vec<ModelId> = {
                        let vq = &self.vqs[id.0 as usize];
                        let groups = &self.groups;
                        vq.model_order(|g| groups.get(&g))
                    };
                    self.fleet.inst_mut(id).registry_mut().set_warm_set(&order);
                    self.wake(id, ready);
                }
                LsoAction::Evict { requests, .. } => {
                    let now = self.clock.now;
                    let evicted = self.fleet.inst_mut(id).evict(&requests, now);
                    for seq in evicted {
                        self.queue.requeue_evicted(seq.req_id, seq.generated, id);
                        self.note_waiting(seq.req_id, 1);
                        if let Some(&g) = self.group_of.get(&seq.req_id) {
                            self.dirty_groups.insert(g);
                        }
                        if let Some(obs) = self.obs.as_deref_mut() {
                            obs.record(
                                now,
                                seq.req_id,
                                TraceEventKind::Evicted { inst: id, generated: seq.generated },
                            );
                        }
                    }
                    self.needs_schedule = true;
                }
                LsoAction::Pull { request, .. } => {
                    let Some(r) = self.queue.get(request) else {
                        continue;
                    };
                    let seq = RunningSeq {
                        req_id: r.id,
                        model: r.model,
                        prompt_tokens: r.input_tokens,
                        target_output: r.output_tokens_hidden.max(1),
                        generated: r.generated,
                        first_token_at: r.first_token_s,
                        arrival_s: r.arrival_s,
                        // try_admit / try_restore normalize prefill and
                        // slice state for evicted re-admissions.
                        prefilled: 0,
                        slice_left: 0,
                    };
                    let now = self.clock.now;
                    let arrival_s = r.arrival_s;
                    let restore = r.evicted_from == Some(id);
                    let res = if restore {
                        self.fleet.inst_mut(id).try_restore(seq, now)
                    } else {
                        self.fleet.inst_mut(id).try_admit(seq, now)
                    };
                    if res.is_ok() {
                        self.note_waiting(request, -1);
                        let prior = self.queue.mark_running(request);
                        // Flight recorder: a pull out of `Waiting` is the
                        // request's *first* service — the edge the RWT
                        // ledger joins predicted-vs-actual wait on. Pulls
                        // out of `Evicted` are re-admissions: a cheap
                        // restore onto the evicting instance, or a
                        // recompute pull elsewhere.
                        if let Some(obs) = self.obs.as_deref_mut() {
                            let wait_s = now - arrival_s;
                            let kind = if restore {
                                TraceEventKind::Restored { inst: id, wait_s }
                            } else {
                                TraceEventKind::Pulled { inst: id, wait_s }
                            };
                            obs.record(now, request, kind);
                            if prior == Some(RequestState::Waiting) {
                                obs.ledger.note_actual(request, wait_s);
                            }
                        }
                        // The group's earliest *unserved* member may have
                        // changed — re-anchor it at the next pass.
                        if let Some(&g) = self.group_of.get(&request) {
                            self.dirty_groups.insert(g);
                        }
                    }
                }
            }
        }
    }

    /// Slice-granular migration: displace sequences whose decode slice
    /// expired this iteration, but only while this instance's waiting
    /// work cannot be admitted (no batch slot, or spare KV below a mean
    /// prompt). Evicted sequences revert to the global queue with their
    /// KV parked in CPU swap; the next scheduling pass may pull them
    /// back here (cheap restore) or onto another instance (recompute).
    /// Each sequence decodes a full slice between boundaries, so every
    /// migration cycle makes progress — no livelock.
    fn migrate_expired_slices(&mut self, id: InstanceId, expired: &[u64]) {
        let idx = id.0 as usize;
        let has_waiting = {
            let vq = &self.vqs[idx];
            let groups = &self.groups;
            let queue = &self.queue;
            vq.groups
                .iter()
                .any(|&g| !waiting_members(groups, queue, g).is_empty())
        };
        if !has_waiting {
            return;
        }
        let now = self.clock.now;
        for &rid in expired {
            let inst = self.fleet.inst(id);
            let admit_prompt = inst.config.mean_prompt_tokens as u64;
            if inst.batch_slots_free() > 0 && inst.spare_tokens() >= admit_prompt {
                break; // waiting work fits without displacing anyone
            }
            // Completion or preemption may have retired it this step.
            if !inst.running().iter().any(|s| s.req_id == rid) {
                continue;
            }
            let evicted = self.fleet.inst_mut(id).evict(&[rid], now);
            for seq in evicted {
                self.queue.requeue_evicted(seq.req_id, seq.generated, id);
                self.note_waiting(seq.req_id, 1);
                if let Some(&g) = self.group_of.get(&seq.req_id) {
                    self.dirty_groups.insert(g);
                }
                if let Some(obs) = self.obs.as_deref_mut() {
                    obs.record(
                        now,
                        seq.req_id,
                        TraceEventKind::Evicted { inst: id, generated: seq.generated },
                    );
                }
            }
            self.needs_schedule = true;
        }
    }

    /// Instance failure (§4 Fault Isolation): the device is gone. Its
    /// virtual queue is dropped — by design it can be rebuilt from the
    /// global queue alone — and every request that was on the instance
    /// reverts to Waiting with progress discarded.
    fn on_fail(&mut self, id: InstanceId) {
        let Some(lost) = self.fleet.fail(id, self.clock.now) else {
            return;
        };
        self.clock.clear_pending(id);
        let lost_ids: Vec<u64> = lost.iter().map(|s| s.req_id).collect();
        for rid in &lost_ids {
            if let Some(&g) = self.group_of.get(rid) {
                self.dirty_groups.insert(g);
            }
        }
        self.queue.fail_instance(id, &lost_ids);
        for rid in &lost_ids {
            self.note_waiting(*rid, 1);
        }
        self.vqs[id.0 as usize].set_order(Vec::new());
        self.views_cache.retain(|v| v.id != id);
        // Reschedule immediately, down the full-solve path: the view set
        // shrank, so the incremental cache is unusable.
        self.sched_force_full = true;
        self.needs_schedule = true;
        self.last_schedule = -1e9;
    }

    /// Autoscaler scale-up: the controller creates the instance and its
    /// cold-start window; the engine grows its per-instance state and
    /// schedules the Provision event.
    fn provision_instance(&mut self, model: ModelId) {
        let Some((id, ready)) = self.fleet.provision(model, self.clock.now) else {
            return;
        };
        let (chunk, slice) = (
            self.cfg.effective_chunk_tokens(),
            self.cfg.effective_slice_tokens(),
        );
        self.fleet.inst_mut(id).set_token_knobs(chunk, slice);
        self.fleet.inst_mut(id).set_trace_chunks(self.cfg.obs.trace);
        self.vqs.push(VirtualQueue::new(id));
        self.agents.push(QlmAgent::new(id, self.cfg.policy.lso()));
        self.clock.add_instance();
        self.clock.push(ready, EventKind::Provision(id));
    }

    /// Cold start finished: the instance joins the scheduler's view set
    /// (a view-set change — the incremental cache is unusable, exactly
    /// as on failure, so the next pass full-solves).
    fn on_provision(&mut self, id: InstanceId) {
        self.fleet.commission(id);
        let view = self.build_view_for(id.0 as usize);
        self.views_cache.push(view);
        self.sched_force_full = true;
        self.needs_schedule = true;
        self.last_schedule = -1e9;
        self.wake(id, self.clock.now);
    }

    /// Scale down by draining: the victim leaves the scheduler's view
    /// set immediately (view-set change ⇒ full solve reassigns its
    /// queued groups), keeps stepping its running batch to completion,
    /// and is decommissioned when idle. No request is killed mid-flight.
    fn begin_drain(&mut self) {
        let Some(id) = self.fleet.begin_drain() else {
            return;
        };
        let idx = id.0 as usize;
        self.views_cache.retain(|v| v.id != id);
        // Its queued groups must be reassigned; mark them dirty (the
        // forced full solve re-places everything anyway, but the dirt
        // keeps delta-path bookkeeping consistent).
        let held: Vec<GroupId> = self.vqs[idx].groups.iter().copied().collect();
        for g in held {
            if let Some(grp) = self.groups.get(&g) {
                // No queue mutation happens here, so the broker's
                // per-shard dirt must be raised by hand — the invariant
                // "a dirty group's shard is dirty" is what lets a pass
                // skip clean shards wholesale.
                self.queue.touch_model(grp.model);
                self.dirty_groups.insert(g);
            }
        }
        self.vqs[idx].set_order(Vec::new());
        self.sched_force_full = true;
        self.needs_schedule = true;
        if self.fleet.inst(id).is_idle() {
            self.decommission(id);
        }
    }

    /// A drained instance leaves the fleet for good.
    fn decommission(&mut self, id: InstanceId) {
        if !self.fleet.decommission(id, self.clock.now) {
            return;
        }
        self.clock.clear_pending(id);
        // KV this instance parked for previously evicted requests is
        // gone with it; those requests are still Waiting in the broker
        // (single replica, §4) and restart from their prompt elsewhere.
        self.queue.fail_instance(id, &[]);
    }

    /// One capacity-subsystem evaluation, run after every scheduler
    /// pass: the controller updates the admission gates and decides;
    /// the engine applies (provisioning / draining touch the event
    /// loop).
    fn capacity_tick(&mut self) {
        let decision = self.fleet.capacity_tick(self.clock.now, &self.views_cache, &self.profiles);
        match decision {
            ScaleDecision::Up { count, model } => {
                for _ in 0..count {
                    self.provision_instance(model);
                }
            }
            ScaleDecision::Down => self.begin_drain(),
            ScaleDecision::Hold => {}
        }
    }

    /// Retire groups the policy reported as unservable (no instance
    /// can serve their model) through the admission controller, so shed
    /// and unservable requests share one accounting path. Their waiting
    /// members are shed in the broker (recorded once, as violations)
    /// and the group dissolves; next pass's delta sees a removal.
    ///
    /// A group is only retired when no fleet growth could rescue it: if
    /// the autoscaler can still provision a tier that hosts the model,
    /// the group is left queued — its backlog pressure drives the
    /// scale-up that makes it servable again (shedding recoverable work
    /// early would throw requests away, the same rule the admission
    /// controller applies at submit time).
    fn shed_unservable_groups(&mut self, unservable: Vec<GroupId>) {
        let rescue_tier = self.fleet.rescue_tier();
        for gid in unservable {
            let Some(g) = self.groups.get(&gid) else { continue };
            if let Some(gpu) = rescue_tier {
                if PerfModel::fits(self.cfg.catalog.get(g.model), gpu) {
                    continue; // a future scale-up can serve this group
                }
            }
            let key = (g.model, g.class, g.mega);
            let members: Vec<u64> = g.members.iter().copied().collect();
            let mut shed = 0u64;
            for rid in members {
                if self.queue.shed(rid) {
                    self.note_waiting(rid, -1);
                    self.group_of.remove(&rid);
                    shed += 1;
                    if let Some(obs) = self.obs.as_deref_mut() {
                        obs.record(self.clock.now, rid, TraceEventKind::Shed);
                    }
                }
            }
            self.fleet.admission.note_shed_unservable(shed);
            let empty = {
                // audit:allow(hot-path-panic): gid was collected from the live group
                // table in this same pass with no intervening removal.
                let g = self.groups.get_mut(&gid).unwrap();
                let group_of = &self.group_of;
                g.members.retain(|rid| group_of.contains_key(rid));
                g.is_empty()
            };
            if empty {
                self.groups.remove(&gid);
                self.queue.open_group_remove(key.0, key.1, key.2, gid);
                for vq in self.vqs.iter_mut() {
                    vq.remove(gid);
                }
                self.dirty_groups.remove(&gid);
                self.removed_groups.push(gid);
                self.policy.group_removed(gid);
            }
        }
    }

    /// Request finished: drop from its group; empty groups leave their
    /// virtual queue (§4: groups dequeue when all requests complete).
    fn on_request_done(&mut self, rid: u64, _inst: InstanceId) {
        let Some(gid) = self.group_of.remove(&rid) else {
            return;
        };
        let grouped = self.cfg.policy.uses_groups();
        let cap = self.grouper.max_group_size();
        let (empty, key) = {
            let Some(g) = self.groups.get_mut(&gid) else {
                return;
            };
            g.members.retain(|&m| m != rid);
            (g.is_empty(), (g.model, g.class, g.mega))
        };
        if empty {
            self.groups.remove(&gid);
            if grouped {
                self.queue.open_group_remove(key.0, key.1, key.2, gid);
            }
            for vq in self.vqs.iter_mut() {
                vq.remove(gid);
            }
            // The group is gone: its scheduler-cache entry and memoized
            // service prices go with it.
            self.dirty_groups.remove(&gid);
            self.removed_groups.push(gid);
            self.policy.group_removed(gid);
            self.needs_schedule = true;
        } else {
            // Shrunk group: it has room again (open-group index), and it
            // must be re-priced and re-anchored at the next pass.
            if grouped && self.groups[&gid].len() < cap {
                self.queue.open_group_insert(key.0, key.1, key.2, gid);
            }
            self.dirty_groups.insert(gid);
        }
    }

    fn maybe_schedule(&mut self) {
        if !self.needs_schedule
            || self.clock.now - self.last_schedule < self.cfg.sched_interval_s
        {
            return;
        }
        self.needs_schedule = false;
        self.last_schedule = self.clock.now;
        // Shard-dirt bookkeeping: count which model shards this pass
        // actually has to look at, and reset their flags. Every queue
        // mutation (and `touch_model` for mutation-free group dirt)
        // raised the flag, so the skip count is exact.
        self.queue.begin_pass();
        // Re-anchor each group's deadline to its earliest *unserved*
        // member: served members have their TTFT already, so a group's
        // binding constraint is the oldest request still waiting. Without
        // this, long-lived batch groups permanently outrank fresh
        // interactive arrivals in deadline order.
        //
        // §Perf: only dirty groups are re-walked. The earliest unserved
        // member can only change when a member transitions state
        // (arrival, pull, evict, completion, failure) — and every one of
        // those marks the group dirty — so this is equivalent to the old
        // all-groups walk, which was O(all queued requests) per pass and
        // capped queue scale.
        let mut earliest = std::mem::take(&mut self.scratch_earliest);
        earliest.clear();
        earliest.extend(
            self.dirty_groups
                .iter()
                .filter_map(|gid| self.groups.get(gid))
                .map(|g| {
                    let e = g
                        .members
                        .iter()
                        .filter(|&&m| {
                            self.queue
                                .get(m)
                                .map(|r| {
                                    matches!(
                                        r.state,
                                        RequestState::Waiting | RequestState::Evicted
                                    )
                                })
                                .unwrap_or(false)
                        })
                        .filter_map(|&m| self.queue.get(m).map(|r| r.arrival_s))
                        .fold(f64::INFINITY, f64::min);
                    (g.id, e)
                }),
        );
        for &(gid, e) in &earliest {
            if e.is_finite() {
                if let Some(g) = self.groups.get_mut(&gid) {
                    g.earliest_arrival_s = e;
                }
            }
        }
        self.scratch_earliest = earliest;
        // audit:allow(wall-clock): measures real scheduler-pass latency for the
        // diagnostics report; sim time comes solely from the event clock.
        let wall = WallInstant::now();

        // One policy pass through the trait seam: the policy sees the
        // group table, the refreshed views, and the engine's dirty
        // tracking, and returns a per-instance order patch.
        let views = self.refresh_views();
        let plan = {
            let ctx = PolicyCtx {
                groups: &self.groups,
                views: &views,
                pinned_model: &self.pinned_model,
                now: self.clock.now,
                dirty: &self.dirty_groups,
                removed: &self.removed_groups,
                force_full: self.sched_force_full,
            };
            self.policy.plan(&ctx)
        };
        // Pass-mix telemetry: fold the policy's reported stats into the
        // cumulative mix (observation only; never feeds back).
        if let (Some(obs), Some(stats)) = (self.obs.as_deref_mut(), plan.stats.as_ref()) {
            obs.sched.absorb(stats);
        }
        let mut touched = std::mem::take(&mut self.scratch_touched);
        touched.clear();
        touched.extend(plan.orders.keys().copied());
        for (id, order) in plan.orders {
            self.vqs[id.0 as usize].set_order(order);
        }
        // Sliding-window chunk control: apply per-instance prefill-budget
        // overrides from chunk-aware policies.
        for (&id, &chunk) in &plan.chunk_tokens {
            if self.fleet.alive(id) {
                self.fleet.inst_mut(id).set_chunk_tokens(Some(chunk));
            }
        }
        // Refresh warm sets for the queues that changed (§5 swapping).
        if self.policy.refreshes_warm_sets() {
            for &id in &touched {
                let idx = id.0 as usize;
                let order: Vec<ModelId> = {
                    let vq = &self.vqs[idx];
                    let groups = &self.groups;
                    vq.model_order(|g| groups.get(&g))
                };
                self.fleet.inst_mut(id).registry_mut().set_warm_set(&order);
            }
        }
        self.scratch_touched = touched;
        self.views_cache = views;
        // Every policy consumes (or rebuilds from scratch over) the full
        // group table per pass, so the dirt is spent either way.
        self.dirty_groups.clear();
        self.removed_groups.clear();
        self.sched_force_full = false;
        self.scheduler_wall_s += wall.elapsed().as_secs_f64();
        self.scheduler_invocations += 1;
        // Capacity subsystem, after the wall capture so the Fig. 20
        // scheduler-overhead metric stays a pure scheduling
        // measurement. Unservable groups retire *after* the dirt
        // clears: their removal must land in `removed_groups` for the
        // NEXT pass, or a delta pass would keep charging their penalty
        // forever. Shedding precedes the tick so the pressure signal
        // sees the post-retirement backlog.
        if !plan.unservable.is_empty() {
            self.shed_unservable_groups(plan.unservable);
        }
        self.capacity_tick();
        // New orders may unblock idle instances.
        let now = self.clock.now;
        let mut ids = std::mem::take(&mut self.scratch_wake);
        ids.clear();
        ids.extend(
            self.fleet
                .instances()
                .iter()
                .filter(|i| self.fleet.alive(i.config.id))
                .map(|i| (i.config.id, now.max(i.busy_until()))),
        );
        for &(id, t) in &ids {
            self.wake(id, t);
        }
        self.scratch_wake = ids;
    }

    fn finish(self) -> RunMetrics {
        let records = collect_records(&self.queue, self.fleet.instances());
        let duration = records
            .iter()
            .filter_map(|r| r.completed_s)
            .fold(0.0_f64, f64::max)
            .max(self.clock.now);
        let device_seconds = self.fleet.device_seconds(duration);
        let (scale_ups, scale_downs) = self.fleet.scale_stats();
        let (shards_scanned, shards_skipped) = self.queue.shard_stats();
        RunMetrics {
            policy: self.cfg.policy.name(),
            records,
            instances: self.fleet.instances().iter().map(instance_metrics).collect(),
            duration_s: duration,
            scheduler_wall_s: self.scheduler_wall_s,
            scheduler_invocations: self.scheduler_invocations,
            device_seconds,
            scale_ups,
            scale_downs,
            compact: self.queue.is_compact().then_some(self.tally),
            shards_scanned,
            shards_skipped,
        }
    }

    /// Shard-dirt counters from the broker: `(scanned, skipped)` shard
    /// totals across all scheduler passes (observability for the
    /// per-shard dirt gate).
    #[doc(hidden)]
    pub fn shard_stats(&self) -> (u64, u64) {
        self.queue.shard_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{EdfPolicy, FcfsPolicy, RoundRobinPolicy};
    use crate::sim::fleet_a100;
    use crate::workload::WorkloadSpec;

    fn small_trace(rate: f64, n: usize) -> Trace {
        let spec = WorkloadSpec::w_a(ModelId(0), rate, n);
        Trace::generate(&spec, 42)
    }

    #[test]
    fn threaded_view_refresh_matches_serial() {
        // The parallel fan-out must be invisible: identical view state
        // whatever the thread count (index-ordered merge).
        let trace = small_trace(5.0, 50);
        let mk = |threads: usize| {
            let mut cfg = SimConfig::new(fleet_a100(8), ModelCatalog::paper(), Policy::qlm());
            cfg.threads = threads;
            Simulation::new(cfg, &trace)
        };
        let mut serial = mk(1);
        let mut par = mk(4);
        assert_eq!(serial.refresh_views_for_bench(), par.refresh_views_for_bench());
    }

    #[test]
    fn worker_pool_is_spawned_once_and_reused() {
        // The persistent pool: one spawn per Simulation, every parallel
        // pass (view refresh + repricing walk) dispatches to the same
        // parked workers. threads=2 over an 8-wide fleet keeps the
        // fan-out gate (len ≥ 2×threads) engaged on every refresh.
        let trace = small_trace(10.0, 300);
        let mut cfg = SimConfig::new(fleet_a100(8), ModelCatalog::paper(), Policy::qlm());
        cfg.threads = 2;
        let sim = Simulation::new(cfg, &trace);
        let pool = Arc::clone(&sim.pool);
        assert_eq!(pool.workers(), 1, "threads=2 ⇒ one spawned worker + the caller");
        let m = sim.run(&trace);
        assert!(m.scheduler_invocations > 1, "{}", m.summary());
        assert!(
            pool.jobs_run() >= m.scheduler_invocations,
            "every pass must dispatch through the pool: {} jobs over {} passes",
            pool.jobs_run(),
            m.scheduler_invocations
        );
        assert_eq!(
            pool.workers(),
            1,
            "the worker set never respawns across {} passes",
            m.scheduler_invocations
        );
    }

    #[test]
    fn finish_records_internally_preempted_sequences() {
        // Horizon accounting with internal preemption active: force a
        // KV-overflow preemption so a sequence parks in the instance's
        // CPU swap (Running in the broker, absent from `waiting_ids()`
        // and `running()`), then close the books — nothing may vanish.
        let trace = small_trace(5.0, 4);
        let cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        let mut sim = Simulation::new(cfg, &trace);
        let inst0 = InstanceId(0);
        sim.fleet.inst_mut(inst0).swap_model(ModelId(0), 0.0);
        let t0 = sim.fleet.inst(inst0).busy_until();
        let perf = sim.fleet.inst(inst0).perf(ModelId(0));
        let per = (perf.token_capacity / 4).saturating_sub(64) as u32;
        for i in 0..4usize {
            let id = sim.queue.submit(Request::from_trace(0, &trace.requests[i]));
            sim.queue.mark_running(id);
            let seq = RunningSeq {
                req_id: id,
                model: ModelId(0),
                prompt_tokens: per,
                target_output: 1000,
                generated: 0,
                first_token_at: None,
                arrival_s: 0.0,
                prefilled: 0,
                slice_left: 0,
            };
            sim.fleet.inst_mut(inst0).try_admit(seq, t0).unwrap();
        }
        let mut now = t0;
        let mut preempted = 0;
        for _ in 0..300 {
            let out = sim.fleet.inst_mut(inst0).step(now);
            now += out.dt;
            preempted += out.preempted;
            if preempted > 0 {
                break;
            }
        }
        assert!(preempted > 0, "expected KV-overflow preemption");
        assert!(sim.fleet.inst(inst0).swapped_len() > 0);
        let m = sim.finish();
        assert_eq!(m.records.len(), 4, "swapped sequences must be recorded");
    }

    #[test]
    fn baseline_orders_invariant_to_group_insertion_order() {
        use crate::coordinator::lso::LsoConfig;
        use crate::workload::SloClass;
        // EDF / FCFS / round-robin plans must be functions of the group
        // *set*, not of store insertion order — exercised straight
        // through the policy seam.
        let trace = small_trace(5.0, 20);
        for which in 0..3 {
            let sim_policy = match which {
                0 => Policy::Edf,
                1 => Policy::VllmFcfs,
                _ => Policy::qlm_with(LsoConfig::without_load_balancing()),
            };
            let run_with = |rev: bool| -> Vec<(u32, Vec<GroupId>)> {
                let cfg = SimConfig::new(fleet_a100(2), ModelCatalog::paper(), sim_policy);
                let mut sim = Simulation::new(cfg, &trace);
                let mut ids: Vec<u64> = (0..20).collect();
                if rev {
                    ids.reverse();
                }
                for i in ids {
                    let gid = GroupId(i);
                    sim.groups.insert(
                        gid,
                        RequestGroup {
                            id: gid,
                            model: ModelId(0),
                            class: SloClass::Interactive,
                            slo: crate::workload::SloTarget::new(20.0, 0.25),
                            earliest_arrival_s: (i % 7) as f64,
                            members: vec![i],
                            mega: false,
                        },
                    );
                }
                let views = sim.refresh_views();
                let ctx = PolicyCtx {
                    groups: &sim.groups,
                    views: &views,
                    pinned_model: &sim.pinned_model,
                    now: 0.0,
                    dirty: &sim.dirty_groups,
                    removed: &sim.removed_groups,
                    force_full: true,
                };
                let mut policy: Box<dyn SchedulingPolicy> = match which {
                    0 => Box::new(EdfPolicy),
                    1 => Box::new(FcfsPolicy),
                    _ => Box::new(RoundRobinPolicy),
                };
                let plan = policy.plan(&ctx);
                let mut orders: Vec<(u32, Vec<GroupId>)> = plan
                    .orders
                    .into_iter()
                    .map(|(id, o)| (id.0, o))
                    .collect();
                orders.sort_by_key(|(id, _)| *id);
                orders
            };
            assert_eq!(run_with(false), run_with(true), "{}", sim_policy.name());
        }
    }

    #[test]
    fn open_group_index_matches_scan_semantics() {
        use crate::workload::TraceRequest;
        let trace = small_trace(5.0, 1);
        let mut cfg = SimConfig::new(fleet_a100(1), ModelCatalog::paper(), Policy::qlm());
        cfg.delta = 1.0;
        cfg.avg_batch = 2; // group cap = 2
        let mut sim = Simulation::new(cfg, &trace);
        let tr = |i: usize| TraceRequest {
            arrival_s: i as f64,
            model: ModelId(0),
            class: crate::workload::SloClass::Interactive,
            slo: crate::workload::SloTarget::new(20.0, 0.25),
            input_tokens: 50,
            output_tokens: 10,
            mega: false,
        };
        for i in 0..5 {
            sim.on_arrival(&tr(i));
        }
        // Cap 2 ⇒ requests 0/1, 2/3, 4 land in three groups.
        assert_eq!(sim.groups.len(), 3);
        let g0 = sim.group_of[&0];
        assert_eq!(sim.group_of[&1], g0);
        assert_ne!(sim.group_of[&2], g0);
        // Completing a member reopens the group; the next compatible
        // arrival must join the *lowest-id* open group (the rule the
        // replaced table scan enforced).
        sim.queue.mark_running(0);
        sim.queue.complete(0, Some(1.0), 1.0, 10);
        sim.on_request_done(0, InstanceId(0));
        sim.on_arrival(&tr(5));
        assert_eq!(sim.group_of[&5], g0, "reopened lowest-id group wins");
        // Full groups never sit in the index (now broker-owned,
        // sharded by model).
        for (key, gids) in sim.queue.open_groups_debug() {
            for gid in gids {
                assert!(sim.groups[&gid].len() < 2, "{key:?} holds a full group");
            }
        }
    }
}
