//! Discrete-event cluster simulator: the paper's 30×A10 + 50×A100 testbed
//! in software. Drives [`crate::backend::Instance`]s token-accurately
//! under a [`crate::baselines::Policy`], with the QLM coordinator on the
//! control path exactly as in Fig. 6.

pub mod engine;
// Public (but doc-hidden) so the bench harness and the property/golden
// suites — external crates — can drive the timer wheel against the
// retained heap baseline directly.
#[doc(hidden)]
pub mod event;
pub mod fleet;
mod fleet_controller;
pub mod profiler;
mod views;

pub use engine::{SimConfig, Simulation};
pub use fleet::{fleet_a100, fleet_from_tiers, fleet_mixed, fleet_of, FleetSpec};
pub use profiler::{profile_theta, ThetaCache};
