//! Figure 5 (§2.4 Insight #3): EDF thrash — interleaved deadlines across
//! models force repeated swaps; grouping requests by model (QLM's
//! request-group ordering, the paper's "Oracle") drains the queue far
//! faster.
//!
//! Setup: a standing multi-model queue with interleaved SLO values; EDF
//! vs QLM on one instance. Metrics: queue drain time and swap count.

use crate::backend::{GpuKind, InstanceConfig, ModelCatalog, ModelId};
use crate::baselines::Policy;
use crate::figures::common::{f1, run_one, Figure, Scale};
use crate::workload::{
    ArrivalProcess, RequestClassSpec, ShareGptSampler, SloClass, Trace, WorkloadSpec,
};

/// Standing queue of `n` requests interleaved across `k` models.
pub fn multi_model_dump(k: usize, n: usize, seed: u64) -> Trace {
    let models: Vec<ModelId> = (0..k as u32).map(ModelId).collect();
    let spec = WorkloadSpec {
        name: format!("mmdump-{k}"),
        streams: vec![
            // Interleaved deadlines: two SLO classes over all models so
            // EDF hops between models chasing deadlines.
            RequestClassSpec {
                class: SloClass::Batch1,
                models: models.clone(),
                arrivals: ArrivalProcess::Dump,
                count: n / 2,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch2,
                models,
                arrivals: ArrivalProcess::Dump,
                count: n - n / 2,
                mega_fraction: 0.0,
            },
        ],
        sampler: ShareGptSampler::default(),
    };
    Trace::generate(&spec, seed)
}

/// (drain time, swaps) for a policy.
pub fn drain(policy: Policy, k: usize, n: usize, seed: u64) -> (f64, u64) {
    let trace = multi_model_dump(k, n, seed);
    let m = run_one(
        &trace,
        vec![InstanceConfig::new(0, GpuKind::A100)],
        ModelCatalog::paper_multi_model(),
        policy,
    );
    let drain_t = m
        .records
        .iter()
        .filter_map(|r| r.completed_s)
        .fold(0.0_f64, f64::max);
    (drain_t, m.total_model_swaps())
}

pub fn run(scale: Scale) -> Figure {
    let n = scale.n(240, 1000);
    let mut fig = Figure::new(
        "fig05",
        "queue drain time: EDF swap-thrash vs QLM model grouping",
        &["models", "edf_drain_s", "edf_swaps", "qlm_drain_s", "qlm_swaps"],
    );
    for k in [2usize, 3] {
        let (ed, es) = drain(Policy::Edf, k, n, 13);
        let (qd, qs) = drain(Policy::qlm(), k, n, 13);
        fig.row(vec![
            format!("{k}"),
            f1(ed),
            format!("{es}"),
            f1(qd),
            format!("{qs}"),
        ]);
    }
    fig.note("paper Fig. 5: EDF drain ≫ Oracle/QLM drain; QLM swaps once per model cluster");
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qlm_swaps_less_and_drains_faster() {
        let (ed, es) = drain(Policy::Edf, 3, 180, 2);
        let (qd, qs) = drain(Policy::qlm(), 3, 180, 2);
        assert!(qs <= es, "qlm swaps {qs} vs edf {es}");
        assert!(qd <= ed * 1.05, "qlm drain {qd} vs edf {ed}");
    }
}
