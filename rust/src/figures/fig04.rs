//! Figure 4 (§2.4 Insight #2): head-of-line blocking from continuous
//! batching is tens of seconds; forced request eviction cuts interactive
//! waiting by orders of magnitude.
//!
//! Setup: one instance saturated with long batch requests; an interactive
//! burst arrives mid-run. Compare interactive TTFT with eviction enabled
//! (QLM) vs disabled (qlm-noevict).

use crate::backend::{GpuKind, InstanceConfig, ModelCatalog, ModelId};
use crate::baselines::Policy;
use crate::coordinator::lso::LsoConfig;
use crate::figures::common::{f2, run_one, Figure, Scale};
use crate::workload::{
    ArrivalProcess, RequestClassSpec, ShareGptSampler, SloClass, Trace, WorkloadSpec,
};

/// Saturating batch load + a delayed interactive burst.
pub fn hol_trace(n_batch: usize, n_interactive: usize, seed: u64) -> Trace {
    let spec = WorkloadSpec {
        name: "hol".into(),
        streams: vec![
            RequestClassSpec {
                class: SloClass::Batch2,
                models: vec![ModelId(0)],
                arrivals: ArrivalProcess::Dump,
                count: n_batch,
                // Long-running mega requests: few completions, saturated
                // KV — the setting where HOL blocking bites (§2.4).
                mega_fraction: 1.0,
            },
            RequestClassSpec {
                class: SloClass::Interactive,
                models: vec![ModelId(0)],
                // Burst arrives while the batch work is mid-flight.
                arrivals: ArrivalProcess::Poisson { rate: 10.0 },
                count: n_interactive,
                mega_fraction: 0.0,
            },
        ],
        sampler: ShareGptSampler::default(),
    };
    Trace::generate(&spec, seed)
}

/// (mean, p99) interactive TTFT under a policy.
pub fn interactive_ttft(policy: Policy, n_batch: usize, seed: u64) -> (f64, f64) {
    let trace = hol_trace(n_batch, 40, seed);
    let m = run_one(
        &trace,
        vec![InstanceConfig::new(0, GpuKind::A10)],
        ModelCatalog::paper(),
        policy,
    );
    let ts: Vec<f64> = m
        .records
        .iter()
        .filter(|r| r.class == SloClass::Interactive)
        .filter_map(|r| r.ttft())
        .collect();
    (
        crate::util::mean(&ts),
        crate::util::percentile(&ts, 99.0),
    )
}

pub fn run(scale: Scale) -> Figure {
    let mut fig = Figure::new(
        "fig04",
        "HOL blocking: interactive TTFT with vs without request eviction",
        &["batch_backlog", "evict_mean_s", "evict_p99_s", "noevict_mean_s", "noevict_p99_s"],
    );
    for &n_batch in &[scale.n(200, 800), scale.n(400, 1600), scale.n(800, 3200)] {
        let (em, ep) = interactive_ttft(Policy::qlm(), n_batch, 7);
        let (nm, np) = interactive_ttft(
            Policy::qlm_with(LsoConfig::without_eviction()),
            n_batch,
            7,
        );
        fig.row(vec![
            format!("{n_batch}"),
            f2(em),
            f2(ep),
            f2(nm),
            f2(np),
        ]);
    }
    fig.note(
        "paper Fig. 4: eviction reduces HOL blocking 100-1000×; \
         shape target: noevict ≫ evict, gap grows with backlog",
    );
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eviction_reduces_interactive_ttft() {
        let (evict_mean, _) = interactive_ttft(Policy::qlm(), 400, 3);
        let (noevict_mean, _) =
            interactive_ttft(Policy::qlm_with(LsoConfig::without_eviction()), 400, 3);
        assert!(
            evict_mean < noevict_mean,
            "evict {evict_mean} vs noevict {noevict_mean}"
        );
    }
}
