//! Figures 9–14 (§8.1, §8.2): single- and multi-model evaluation —
//! request throughput, SLO attainment, and per-LSO ablations.
//!
//! Quick scale uses a 4-instance fleet with proportionally scaled arrival
//! rates; full scale uses the paper's 50 A100s. Rates sweep from
//! under-provisioned to overloaded so the SLO curves show the paper's
//! shape: everyone fails far above capacity, QLM holds attainment highest
//! as pressure rises.

use crate::backend::{ModelCatalog, ModelId};
use crate::baselines::Policy;
use crate::coordinator::lso::LsoConfig;
use crate::figures::common::{f1, pct, run_one, run_policies, Figure, Scale};
use crate::sim::fleet_a100;
use crate::workload::{Trace, WorkloadSpec};

fn fleet_size(scale: Scale) -> u32 {
    scale.n(4, 50) as u32
}

/// Interactive arrival rates (req/s) swept for W_A, scaled to fleet.
fn rates(scale: Scale) -> Vec<f64> {
    match scale {
        Scale::Quick => vec![15.0, 40.0, 100.0, 250.0],
        Scale::Full => vec![125.0, 250.0, 500.0, 1000.0, 2000.0],
    }
}

fn w_a_trace(rate: f64, scale: Scale, seed: u64) -> Trace {
    Trace::generate(
        &WorkloadSpec::w_a(ModelId(1), rate, scale.n(1200, 3500)),
        seed,
    )
}

fn w_b_trace(rate: f64, scale: Scale, seed: u64) -> Trace {
    // Batch-1: fine-tuned Mistral-7B + Llama-70B; Batch-2: fine-tuned
    // Vicuna-13B + Llama-70B (§8 workloads).
    Trace::generate(
        &WorkloadSpec::w_b(
            vec![ModelId(3), ModelId(4)],
            vec![ModelId(5), ModelId(6)],
            rate,
            scale.n(1200, 3500),
        ),
        seed,
    )
}

/// Fig. 9: single-model serving throughput at the rate where QLM holds
/// SLOs (paper: 0.5K req/s interactive).
pub fn fig09(scale: Scale) -> Figure {
    let rate = scale.f(40.0, 500.0);
    let trace = w_a_trace(rate, scale, 21);
    let fleet = fleet_a100(fleet_size(scale));
    let catalog = ModelCatalog::paper();
    let mut fig = Figure::new(
        "fig09",
        "single-model throughput (W_A)",
        &["policy", "req_per_s", "tok_per_s", "util"],
    );
    for m in run_policies(&trace, &fleet, &catalog) {
        fig.row(vec![
            m.policy.clone(),
            f1(m.throughput_rps()),
            f1(m.token_throughput()),
            pct(m.mean_utilization()),
        ]);
    }
    fig.note("paper Fig. 9: QLM ≈ +20% vs vLLM/EDF, +50% vs SHEPHERD");
    fig
}

/// Fig. 10: single-model SLO attainment vs interactive arrival rate.
pub fn fig10(scale: Scale) -> Figure {
    let fleet = fleet_a100(fleet_size(scale));
    let catalog = ModelCatalog::paper();
    let mut fig = Figure::new(
        "fig10",
        "single-model SLO attainment vs arrival rate (W_A)",
        &["rate_rps", "qlm", "edf", "vllm", "shepherd"],
    );
    for rate in rates(scale) {
        let trace = w_a_trace(rate, scale, 22);
        let ms = run_policies(&trace, &fleet, &catalog);
        fig.row(vec![
            f1(rate),
            pct(ms[0].slo_attainment()),
            pct(ms[1].slo_attainment()),
            pct(ms[2].slo_attainment()),
            pct(ms[3].slo_attainment()),
        ]);
    }
    fig.note(
        "paper Fig. 10: QLM 40-90% above vLLM, 50-90% above SHEPHERD; \
         all fail far beyond capacity",
    );
    fig
}

/// LSO ablation rows for a trace/fleet (figs. 11 and 14).
fn ablation_rows(fig: &mut Figure, trace: &Trace, fleet_n: u32, catalog: &ModelCatalog) {
    let fleet = fleet_a100(fleet_n);
    let variants: Vec<(&str, Policy)> = vec![
        ("qlm-all", Policy::qlm()),
        ("no-ordered-pull", Policy::qlm_with(LsoConfig::without_ordered_pulling())),
        ("no-eviction", Policy::qlm_with(LsoConfig::without_eviction())),
        ("no-load-balance", Policy::qlm_with(LsoConfig::without_load_balancing())),
        ("no-model-swap", Policy::qlm_with(LsoConfig::without_swapping())),
    ];
    for (name, p) in variants {
        let m = run_one(trace, fleet.clone(), catalog.clone(), p);
        fig.row(vec![
            name.into(),
            pct(m.slo_attainment()),
            f1(m.throughput_rps()),
            format!("{}", m.total_model_swaps()),
            format!("{}", m.total_evictions()),
        ]);
    }
}

/// Fig. 11: single-model LSO ablation at the Fig. 9 operating point.
pub fn fig11(scale: Scale) -> Figure {
    let trace = w_a_trace(scale.f(40.0, 500.0), scale, 23);
    let mut fig = Figure::new(
        "fig11",
        "single-model LSO ablation (W_A)",
        &["variant", "slo", "req_per_s", "swaps", "evictions"],
    );
    ablation_rows(&mut fig, &trace, fleet_size(scale), &ModelCatalog::paper());
    fig.note(
        "paper Fig. 11: pulling + eviction drive SLOs; \
         model swapping is a no-op single-model",
    );
    fig
}

/// Fig. 12: multi-model throughput vs Batch-1 arrival rate.
pub fn fig12(scale: Scale) -> Figure {
    let fleet = fleet_a100(scale.n(3, 40) as u32);
    let catalog = ModelCatalog::paper_multi_model();
    let mut fig = Figure::new(
        "fig12",
        "multi-model throughput vs Batch-1 rate (W_B)",
        &["rate_rps", "qlm", "edf", "vllm", "shepherd"],
    );
    for rate in rates(scale).into_iter().take(4) {
        let trace = w_b_trace(rate * 0.5, scale, 24);
        let ms = run_policies(&trace, &fleet, &catalog);
        fig.row(vec![
            f1(rate * 0.5),
            f1(ms[0].throughput_rps()),
            f1(ms[1].throughput_rps()),
            f1(ms[2].throughput_rps()),
            f1(ms[3].throughput_rps()),
        ]);
    }
    fig.note("paper Fig. 12: QLM 3-4× baselines (request groups amortize swaps)");
    fig
}

/// Fig. 13: multi-model SLO attainment vs Batch-1 rate.
pub fn fig13(scale: Scale) -> Figure {
    let fleet = fleet_a100(scale.n(3, 40) as u32);
    let catalog = ModelCatalog::paper_multi_model();
    let mut fig = Figure::new(
        "fig13",
        "multi-model SLO attainment vs Batch-1 rate (W_B)",
        &["rate_rps", "qlm", "edf", "vllm", "shepherd"],
    );
    for rate in rates(scale).into_iter().take(4) {
        let trace = w_b_trace(rate * 0.5, scale, 25);
        let ms = run_policies(&trace, &fleet, &catalog);
        fig.row(vec![
            f1(rate * 0.5),
            pct(ms[0].slo_attainment()),
            pct(ms[1].slo_attainment()),
            pct(ms[2].slo_attainment()),
            pct(ms[3].slo_attainment()),
        ]);
    }
    fig.note(
        "paper Fig. 13: QLM >90% below 0.5K req/s; \
         baselines ignore swap cost and fall behind",
    );
    fig
}

/// Fig. 14: multi-model LSO ablation.
pub fn fig14(scale: Scale) -> Figure {
    let trace = w_b_trace(scale.f(10.0, 250.0), scale, 26);
    let mut fig = Figure::new(
        "fig14",
        "multi-model LSO ablation (W_B)",
        &["variant", "slo", "req_per_s", "swaps", "evictions"],
    );
    ablation_rows(
        &mut fig,
        &trace,
        scale.n(3, 40) as u32,
        &ModelCatalog::paper_multi_model(),
    );
    fig.note("paper Fig. 14: model swapping (warm start) contributes most multi-model");
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_qlm_dominates_at_moderate_load() {
        let fleet = fleet_a100(2);
        let catalog = ModelCatalog::paper();
        let trace = w_a_trace(15.0, Scale::Quick, 1);
        let ms = run_policies(&trace, &fleet, &catalog);
        let qlm = ms[0].slo_attainment();
        for m in &ms[1..] {
            assert!(
                qlm >= m.slo_attainment() - 0.02,
                "qlm {} vs {} {}",
                qlm,
                m.policy,
                m.slo_attainment()
            );
        }
    }

    #[test]
    fn fig12_qlm_beats_baselines_multi_model() {
        let fleet = fleet_a100(2);
        let catalog = ModelCatalog::paper_multi_model();
        let trace = w_b_trace(8.0, Scale::Quick, 2);
        let ms = run_policies(&trace, &fleet, &catalog);
        let qlm = ms[0].throughput_rps();
        // QLM must beat vLLM and SHEPHERD on multi-model throughput.
        assert!(
            qlm > ms[2].throughput_rps() * 0.99,
            "qlm {qlm} vs vllm {}",
            ms[2].throughput_rps()
        );
        assert!(
            qlm > ms[3].throughput_rps() * 0.99,
            "qlm {qlm} vs shepherd {}",
            ms[3].throughput_rps()
        );
    }

    #[test]
    fn ablations_produce_distinct_rows() {
        let f = fig11(Scale::Quick);
        assert_eq!(f.rows.len(), 5);
        // Single-model: swapping ablation must not change SLO materially.
        let slo_all: f64 = f.rows[0][1].trim_end_matches('%').parse().unwrap();
        let slo_noswap: f64 = f.rows[4][1].trim_end_matches('%').parse().unwrap();
        assert!((slo_all - slo_noswap).abs() < 15.0);
    }
}
