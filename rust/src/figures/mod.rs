//! Figure harness: regenerates every table/figure in the paper's
//! evaluation (§2.4 motivation + §8). Each `figNN` function returns a
//! [`Figure`] of printable rows; `qlm figures --fig N` runs one,
//! `qlm figures` runs all. DESIGN.md's experiment index maps figures to
//! modules; EXPERIMENTS.md records paper-vs-measured.
//!
//! Scale: the default "quick" scale shrinks fleets/traces so the whole
//! suite runs in minutes on CPU; `--full` uses paper-sized fleets. The
//! *shape* of each result (who wins, by what factor, where crossovers
//! fall) is the reproduction target, not absolute numbers — the substrate
//! is a calibrated simulator (DESIGN.md §Substitutions).

pub mod common;
pub mod fig01;
pub mod fig03;
pub mod fig04;
pub mod fig05;
pub mod fig08;
pub mod eval;
pub mod robustness;
pub mod estimator;

pub use common::{Figure, Scale};

/// Run one figure by number; None ⇒ unknown id.
pub fn run_figure(id: u32, scale: Scale) -> Option<Figure> {
    Some(match id {
        1 => fig01::run(scale),
        3 => fig03::run(scale),
        4 => fig04::run(scale),
        5 => fig05::run(scale),
        8 => fig08::run(scale),
        9 => eval::fig09(scale),
        10 => eval::fig10(scale),
        11 => eval::fig11(scale),
        12 => eval::fig12(scale),
        13 => eval::fig13(scale),
        14 => eval::fig14(scale),
        15 => robustness::fig15(scale),
        16 => robustness::fig16(scale),
        17 => robustness::fig17(scale),
        18 => estimator::fig18(scale),
        19 => estimator::fig19(scale),
        20 => estimator::fig20(scale),
        _ => return None,
    })
}

/// All figure ids in paper order.
pub const ALL_FIGURES: &[u32] = &[1, 3, 4, 5, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20];
