//! Shared helpers for the figure harness: experiment scale, policy
//! sweeps, and text-table rendering.

use crate::backend::{InstanceConfig, ModelCatalog};
use crate::baselines::Policy;
use crate::metrics::RunMetrics;
use crate::sim::{SimConfig, Simulation};
use crate::workload::Trace;

/// Experiment scale: quick (CI-sized) or full (paper-sized fleets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    /// Scale a paper-sized count down for quick runs.
    pub fn n(&self, quick: usize, full: usize) -> usize {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }

    pub fn f(&self, quick: f64, full: f64) -> f64 {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
}

/// A rendered figure: rows of (label, values) with column headers.
#[derive(Debug, Clone)]
pub struct Figure {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (paper-expected shape, caveats).
    pub notes: Vec<String>,
}

impl Figure {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Self {
        Figure {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }
}

/// Run one (trace, fleet, policy) simulation.
pub fn run_one(
    trace: &Trace,
    fleet: Vec<InstanceConfig>,
    catalog: ModelCatalog,
    policy: Policy,
) -> RunMetrics {
    let cfg = SimConfig::new(fleet, catalog, policy);
    Simulation::new(cfg, trace).run(trace)
}

/// Run all four headline policies on the same workload.
pub fn run_policies(
    trace: &Trace,
    fleet: &[InstanceConfig],
    catalog: &ModelCatalog,
) -> Vec<RunMetrics> {
    [
        Policy::qlm(),
        Policy::Edf,
        Policy::VllmFcfs,
        Policy::Shepherd,
    ]
    .into_iter()
    .map(|p| run_one(trace, fleet.to_vec(), catalog.clone(), p))
    .collect()
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut f = Figure::new("fig0", "test", &["a", "bbbb"]);
        f.row(vec!["1".into(), "2".into()]);
        f.note("shape");
        let r = f.render();
        assert!(r.contains("fig0"));
        assert!(r.contains("note: shape"));
        assert_eq!(r.lines().count(), 5);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut f = Figure::new("x", "t", &["a"]);
        f.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn scale_selects() {
        assert_eq!(Scale::Quick.n(1, 10), 1);
        assert_eq!(Scale::Full.n(1, 10), 10);
    }
}
