//! Figure 8 (§8): input/output token distribution of the (synthetic)
//! ShareGPT workload — validates the fitted sampler's shape against the
//! published histogram (heavy right tail, output longer than input).

use crate::figures::common::{f1, Figure, Scale};
use crate::util::{Histogram, Rng};
use crate::workload::ShareGptSampler;

pub fn run(scale: Scale) -> Figure {
    let n = scale.n(3500, 35_000);
    let s = ShareGptSampler::default();
    let mut rng = Rng::new(8);
    let mut hin = Histogram::new(2048.0, 16);
    let mut hout = Histogram::new(2048.0, 16);
    let mut ins = Vec::with_capacity(n);
    let mut outs = Vec::with_capacity(n);
    for _ in 0..n {
        let (i, o) = s.sample(&mut rng);
        hin.record(i as f64);
        hout.record(o as f64);
        ins.push(i as f64);
        outs.push(o as f64);
    }
    let mut fig = Figure::new(
        "fig08",
        "ShareGPT token distribution (fitted sampler)",
        &["bucket_tokens", "input_count", "output_count"],
    );
    for ((c, i), (_, o)) in hin.rows().into_iter().zip(hout.rows()) {
        fig.row(vec![f1(c), format!("{i}"), format!("{o}")]);
    }
    fig.note(format!(
        "input: mean={:.0} p50={:.0} p99={:.0}; output: mean={:.0} p50={:.0} p99={:.0} (ShareGPT: in≈161, out≈338, heavy tail)",
        crate::util::mean(&ins),
        crate::util::percentile(&ins, 50.0),
        crate::util::percentile(&ins, 99.0),
        crate::util::mean(&outs),
        crate::util::percentile(&outs, 50.0),
        crate::util::percentile(&outs, 99.0),
    ));
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_has_mass_and_tail() {
        let f = run(Scale::Quick);
        assert_eq!(f.rows.len(), 17); // 16 bins + overflow
        let outputs: Vec<u64> = f
            .rows
            .iter()
            .map(|r| r[2].parse::<u64>().unwrap())
            .collect();
        let total: u64 = outputs.iter().sum();
        assert_eq!(total, 3500);
        // Right tail exists but is small.
        let tail: u64 = outputs[8..].iter().sum();
        assert!(tail > 0 && tail < total / 4);
    }
}
