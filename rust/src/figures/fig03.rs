//! Figure 3 (§2.4 Insight #1): requests in a continuous-batching system
//! have predictable waiting times — TTFT grows linearly with queue
//! position, R² ≈ 0.99 across model sizes.
//!
//! Setup: a standing queue (Dump arrivals) drained by one instance per
//! model; measured TTFT per queue position vs the RWT estimator's linear
//! prediction.

use crate::backend::{GpuKind, InstanceConfig, ModelCatalog, ModelId, PerfModel};
use crate::baselines::Policy;
use crate::coordinator::rwt::{ProfileTable, RwtEstimator};
use crate::figures::common::{f1, f3, run_one, Figure, Scale};
use crate::util::{linear_fit, r_squared};
use crate::workload::{ArrivalProcess, RequestClassSpec, SloClass, Trace, WorkloadSpec};

/// Standing-queue workload for one model.
pub fn dump_trace(model: ModelId, n: usize, seed: u64) -> Trace {
    let spec = WorkloadSpec {
        name: format!("dump-{n}"),
        streams: vec![RequestClassSpec {
            class: SloClass::Batch2,
            models: vec![model],
            arrivals: ArrivalProcess::Dump,
            count: n,
            mega_fraction: 0.0,
        }],
        sampler: Default::default(),
    };
    Trace::generate(&spec, seed)
}

/// (positions, measured waits, predicted waits, r², slope) for one model.
pub fn wait_curve(model: ModelId, n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64) {
    let catalog = ModelCatalog::paper();
    let trace = dump_trace(model, n, seed);
    // The paper measures vanilla vLLM (FCFS continuous batching).
    let m = run_one(
        &trace,
        vec![InstanceConfig::new(0, GpuKind::A100)],
        catalog.clone(),
        Policy::VllmFcfs,
    );
    // Measured: TTFT by arrival order (= queue position for Dump).
    let mut recs = m.records.clone();
    recs.sort_by_key(|r| r.id);
    let measured: Vec<f64> = recs.iter().filter_map(|r| r.ttft()).collect();
    let positions: Vec<f64> = (0..measured.len()).map(|i| i as f64).collect();

    // Predicted: Eq. 2 with hardware-profiled Θ (§6 Offline Profiling).
    let est = RwtEstimator::new(ProfileTable::from_trace(&trace));
    let mut perf = PerfModel::profile(catalog.get(model), GpuKind::A100, 161.0);
    perf.measured_theta = Some(crate::sim::profile_theta(
        model,
        GpuKind::A100,
        &catalog,
        0xBEEF,
    ));
    let profile = est.profiles.get(model, SloClass::Batch2, false);
    // Measured TTFTs include the instance's cold start (storage→CPU→GPU
    // model load at t=0); the prediction charges the same constant.
    let cold_start = perf.swap_storage_cpu_s + perf.swap_cpu_gpu_s;
    let predicted: Vec<f64> = positions
        .iter()
        .map(|&q| {
            est.request_wait(q as usize, &perf, &profile).0 + perf.prefill_s + cold_start
        })
        .collect();
    let r2 = r_squared(&predicted, &measured);
    (positions, measured, predicted, r2)
}

pub fn run(scale: Scale) -> Figure {
    let n = scale.n(1200, 4000);
    let mut fig = Figure::new(
        "fig03",
        "waiting time vs queue position (linear, R²≈0.99)",
        &["model", "pos", "measured_wait_s", "rwt_pred_s"],
    );
    let catalog = ModelCatalog::paper();
    for model in catalog.ids() {
        let (pos, meas, pred, r2) = wait_curve(model, n, 3);
        let name = &catalog.get(model).name;
        for i in (0..meas.len()).step_by((meas.len() / 8).max(1)) {
            fig.row(vec![
                name.clone(),
                f1(pos[i]),
                f1(meas[i]),
                f1(pred[i]),
            ]);
        }
        let (_, slope) = linear_fit(&pos, &meas);
        fig.note(format!(
            "{name}: R²={} slope={}s/request (paper: linear, R²=0.99)",
            f3(r2),
            f3(slope)
        ));
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiting_time_linear_with_high_r2() {
        // The core Insight-#1 claim at reduced scale. Vicuna-13B has the
        // smallest steady batch, so a 1000-deep queue has real waiting.
        let (_pos, meas, _pred, r2) = wait_curve(ModelId(1), 1000, 9);
        assert!(meas.len() >= 990);
        assert!(r2 > 0.85, "R² = {r2}");
    }

    #[test]
    fn figure_renders_all_models() {
        let f = run(Scale::Quick);
        assert_eq!(f.notes.len(), 3);
        assert!(f.rows.len() >= 9);
    }
}
