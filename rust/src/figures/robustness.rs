//! Figures 15–17 (§8.3): robustness to hardware heterogeneity, mega
//! prompts, and queue size/burstiness.

use crate::backend::{ModelCatalog, ModelId};
use crate::baselines::Policy;
use crate::coordinator::lso::LsoConfig;
use crate::figures::common::{f1, pct, run_one, Figure, Scale};
use crate::sim::{fleet_a100, fleet_mixed};
use crate::workload::{Trace, WorkloadSpec};

/// Fig. 15: hardware heterogeneity — QLM's RWT-aware placement vs a
/// round-robin assignment on A10/A100 mixes.
pub fn fig15(scale: Scale) -> Figure {
    let total = scale.n(6, 40) as u32;
    let rate = scale.f(24.0, 400.0);
    let reqs = scale.n(1000, 3500);
    let mut fig = Figure::new(
        "fig15",
        "hardware heterogeneity: throughput on A10/A100 mixes",
        &["a10_frac", "qlm_rps", "roundrobin_rps", "qlm_slo", "rr_slo"],
    );
    // Mistral-7B fits both device kinds (Llama-70B would exclude A10s).
    let catalog = ModelCatalog::paper();
    for frac in [0.0, 0.2, 0.5, 0.8] {
        let fleet = fleet_mixed(total, frac);
        let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), rate, reqs), 31);
        let q = run_one(&trace, fleet.clone(), catalog.clone(), Policy::qlm());
        let rr = run_one(
            &trace,
            fleet,
            catalog.clone(),
            Policy::qlm_with(LsoConfig::without_load_balancing()),
        );
        fig.row(vec![
            f1(frac),
            f1(q.throughput_rps()),
            f1(rr.throughput_rps()),
            pct(q.slo_attainment()),
            pct(rr.slo_attainment()),
        ]);
    }
    fig.note("paper Fig. 15: QLM advantage largest at 20-50% A10 (most heterogeneous)");
    fig
}

/// Fig. 16: mega-prompt workload (W_C) — SLO attainment vs the fraction
/// of 3K-4K-token prompts.
pub fn fig16(scale: Scale) -> Figure {
    // Mega prompts only block when KV memory is genuinely scarce: run
    // Mistral-7B on A10s (8 GiB of KV headroom, ~16 concurrent mega
    // prompts) — the memory regime of the paper's testbed.
    let fleet = crate::sim::fleet_mixed(scale.n(3, 30) as u32, 1.0);
    let rate = scale.f(15.0, 250.0);
    let reqs = scale.n(1000, 3500);
    let catalog = ModelCatalog::paper();
    let mut fig = Figure::new(
        "fig16",
        "mega-prompt workload (W_C): SLO attainment vs mega fraction",
        &["mega_frac", "qlm", "vllm", "shepherd"],
    );
    for frac in [0.0, 0.05, 0.15, 0.4] {
        let spec = WorkloadSpec::w_c(vec![ModelId(0)], vec![ModelId(0)], rate, reqs, frac);
        let trace = Trace::generate(&spec, 32);
        let q = run_one(&trace, fleet.clone(), catalog.clone(), Policy::qlm());
        let v = run_one(&trace, fleet.clone(), catalog.clone(), Policy::VllmFcfs);
        let s = run_one(&trace, fleet.clone(), catalog.clone(), Policy::Shepherd);
        fig.row(vec![
            pct(frac),
            pct(q.slo_attainment()),
            pct(v.slo_attainment()),
            pct(s.slo_attainment()),
        ]);
    }
    fig.note("paper Fig. 16: QLM isolates mega prompts; benefit shrinks as they dominate");
    fig
}

/// Fig. 17: SLO attainment vs queue size — arrival-rate sweep of W_B,
/// queue size measured as the time-averaged waiting count (Little's law).
pub fn fig17(scale: Scale) -> Figure {
    let fleet = fleet_a100(scale.n(3, 40) as u32);
    let catalog = ModelCatalog::paper_multi_model();
    let reqs = scale.n(900, 3500);
    let mut fig = Figure::new(
        "fig17",
        "SLO attainment vs queue size (W_B rate sweep)",
        &["mean_queue", "qlm", "edf", "vllm", "shepherd"],
    );
    let rates = [
        scale.f(4.0, 100.0),
        scale.f(10.0, 250.0),
        scale.f(25.0, 500.0),
        scale.f(60.0, 1000.0),
    ];
    for rate in rates {
        let spec = WorkloadSpec::w_b(
            vec![ModelId(3), ModelId(4)],
            vec![ModelId(5), ModelId(6)],
            rate,
            reqs,
        );
        let trace = Trace::generate(&spec, 33);
        let ms: Vec<_> = [
            Policy::qlm(),
            Policy::Edf,
            Policy::VllmFcfs,
            Policy::Shepherd,
        ]
        .into_iter()
        .map(|p| run_one(&trace, fleet.clone(), catalog.clone(), p))
        .collect();
        // Time-averaged queue size under QLM (Little: Σ wait / duration).
        let total_wait: f64 = ms[0]
            .records
            .iter()
            .filter_map(|r| r.ttft())
            .sum();
        let mean_q = total_wait / ms[0].duration_s.max(1e-9);
        fig.row(vec![
            f1(mean_q),
            pct(ms[0].slo_attainment()),
            pct(ms[1].slo_attainment()),
            pct(ms[2].slo_attainment()),
            pct(ms[3].slo_attainment()),
        ]);
    }
    fig.note("paper Fig. 17: at queue≈0 all tie; QLM holds attainment as queues grow");
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig15_qlm_at_least_round_robin_on_heterogeneous_fleet() {
        let fleet = fleet_mixed(4, 0.5);
        let catalog = ModelCatalog::paper();
        let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 15.0, 600), 4);
        let q = run_one(&trace, fleet.clone(), catalog.clone(), Policy::qlm());
        let rr = run_one(
            &trace,
            fleet,
            catalog,
            Policy::qlm_with(LsoConfig::without_load_balancing()),
        );
        assert!(
            q.slo_attainment() >= rr.slo_attainment() - 0.02,
            "qlm {} vs rr {}",
            q.slo_attainment(),
            rr.slo_attainment()
        );
    }

    #[test]
    fn fig17_low_load_ties() {
        // At near-zero queue, QLM ≈ baselines (paper: no benefit).
        let fleet = fleet_a100(2);
        let catalog = ModelCatalog::paper();
        let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 2.0, 200), 5);
        let q = run_one(&trace, fleet.clone(), catalog.clone(), Policy::qlm());
        let v = run_one(&trace, fleet, catalog, Policy::VllmFcfs);
        assert!((q.slo_attainment() - v.slo_attainment()).abs() < 0.1);
    }
}
