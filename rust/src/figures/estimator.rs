//! Figures 18–20 (§8.3): RWT estimator accuracy, request-group size (δ)
//! trade-off, and global-scheduler overhead.

use std::collections::BTreeMap;
// audit:allow(wall-clock): Fig. 20 measures real scheduler-pass latency on
// the host; the stopwatch never feeds back into any plan or sim clock.
use std::time::Instant;

use crate::backend::{GpuKind, ModelCatalog, ModelId, PerfModel};
use crate::baselines::Policy;
use crate::coordinator::request_group::{GroupId, RequestGroup};
use crate::coordinator::rwt::{ProfileTable, RwtEstimator};
use crate::coordinator::scheduler::{GlobalScheduler, InstanceView, SchedulerConfig, SolverKind};
use crate::figures::common::{f1, f3, pct, Figure, Scale};
use crate::sim::{fleet_a100, SimConfig, Simulation};
use crate::util::r_squared;
use crate::workload::{SloClass, Trace, WorkloadSpec};

/// Fig. 18: estimator accuracy (R² of predicted vs measured request
/// waiting time) as the queue grows, per model. Queue size is counted in
/// request groups (δ·avg_batch = 256 requests per group), as in §8.3.
pub fn fig18(scale: Scale) -> Figure {
    let mut fig = Figure::new(
        "fig18",
        "RWT estimator accuracy vs queue size (request groups)",
        &["model", "groups_in_queue", "r2"],
    );
    let catalog = ModelCatalog::paper();
    let group_sz = 256usize; // δ=4 × avg_batch=64
    for model in catalog.ids() {
        for n_groups in [1usize, 2, 4, scale.n(6, 8)] {
            let (pred, actual) = wait_pairs(model, n_groups * group_sz, 40);
            let r2 = r_squared(&pred, &actual);
            fig.row(vec![
                catalog.get(model).name.clone(),
                format!("{n_groups}"),
                f3(r2),
            ]);
        }
    }
    fig.note(
        "paper Fig. 18: accuracy rises with queue size, ≈0.99 by 4 groups; \
         short queues are conservatively overestimated",
    );
    fig
}

/// Predicted (Eq. 2, profiled Θ) vs measured TTFT for every request in a
/// standing queue of `n` requests.
fn wait_pairs(model: ModelId, n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let (_pos, meas, pred, _r2) = crate::figures::fig03::wait_curve(model, n, seed);
    (pred, meas)
}

/// Fig. 19: δ trade-off — SLO attainment (decision granularity) vs
/// scheduler overhead, δ ∈ {1, 2, 4, 16}.
pub fn fig19(scale: Scale) -> Figure {
    let mut fig = Figure::new(
        "fig19",
        "request-group size δ: performance vs scheduler overhead",
        &["delta", "slo", "req_per_s", "sched_ms_per_invocation", "invocations"],
    );
    let fleet = fleet_a100(scale.n(3, 20) as u32);
    let trace = Trace::generate(
        &WorkloadSpec::w_a(ModelId(1), scale.f(18.0, 300.0), scale.n(1000, 3500)),
        19,
    );
    for delta in [1.0, 2.0, 4.0, 16.0] {
        let mut cfg = SimConfig::new(fleet.clone(), ModelCatalog::paper(), Policy::qlm());
        cfg.delta = delta;
        let m = Simulation::new(cfg, &trace).run(&trace);
        let per_inv = if m.scheduler_invocations > 0 {
            1000.0 * m.scheduler_wall_s / m.scheduler_invocations as f64
        } else {
            0.0
        };
        fig.row(vec![
            f1(delta),
            pct(m.slo_attainment()),
            f1(m.throughput_rps()),
            f3(per_inv),
            format!("{}", m.scheduler_invocations),
        ]);
    }
    fig.note(
        "paper Fig. 19: δ=1 best performance / highest overhead; \
         δ=4 ≈ no degradation at low overhead",
    );
    fig
}

/// Fig. 20: global-scheduler solve time vs queue size (number of queued
/// requests), for the greedy production path and the exact MILP.
pub fn fig20(scale: Scale) -> Figure {
    let mut fig = Figure::new(
        "fig20",
        "global scheduler overhead vs queue size",
        &["queue_requests", "groups", "solver", "solve_ms", "ms_per_group"],
    );
    let catalog = ModelCatalog::paper_multi_model();
    let est = RwtEstimator::new(ProfileTable::default());
    let group_sz = 256usize; // δ=4 × avg_batch=64

    let sizes: Vec<usize> = match scale {
        Scale::Quick => vec![1_000, 10_000, 50_000, 100_000],
        Scale::Full => vec![1_000, 10_000, 50_000, 100_000, 400_000],
    };
    // A 10-instance view set.
    let views: Vec<InstanceView> = (0..10)
        .map(|i| {
            let mut perf_for = BTreeMap::new();
            let mut swap_time = BTreeMap::new();
            for m in catalog.ids() {
                if let Some(p) = PerfModel::try_profile(catalog.get(m), GpuKind::A100, 161.0) {
                    swap_time.insert(m, p.swap_cpu_gpu_s);
                    perf_for.insert(m, p);
                }
            }
            InstanceView {
                id: crate::backend::InstanceId(i),
                active_model: Some(ModelId(0)),
                perf_for,
                swap_time,
                executing: None,
            }
        })
        .collect();

    for &n_requests in &sizes {
        let n_groups = (n_requests / group_sz).max(1);
        let groups: Vec<RequestGroup> = (0..n_groups)
            .map(|g| RequestGroup {
                id: GroupId(g as u64),
                model: ModelId((g % 4) as u32),
                class: SloClass::Batch1,
                slo: crate::workload::SloTarget::new(60.0 + (g % 7) as f64 * 300.0, 1.0),
                earliest_arrival_s: 0.0,
                members: (0..group_sz as u64).collect(),
                mega: false,
            })
            .collect();
        let refs: Vec<&RequestGroup> = groups.iter().collect();
        let sched = GlobalScheduler::new(
            SchedulerConfig {
                solver: SolverKind::Greedy,
                ..Default::default()
            },
            est.clone(),
        );
        // audit:allow(wall-clock): the measured quantity IS wall latency.
        let t0 = Instant::now();
        let a = sched.schedule(&refs, &views, 0.0);
        let ms = 1000.0 * t0.elapsed().as_secs_f64();
        fig.row(vec![
            format!("{n_requests}"),
            format!("{}", a.stats.groups),
            "greedy".into(),
            f1(ms),
            f3(ms / n_groups as f64),
        ]);
    }
    // Exact MILP on a small queue for reference.
    let small: Vec<RequestGroup> = (0..5)
        .map(|g| RequestGroup {
            id: GroupId(g as u64),
            model: ModelId((g % 2) as u32),
            class: SloClass::Batch1,
            slo: crate::workload::SloTarget::new(60.0, 1.0),
            earliest_arrival_s: 0.0,
            members: (0..group_sz as u64).collect(),
            mega: false,
        })
        .collect();
    let small_refs: Vec<&RequestGroup> = small.iter().collect();
    let sched = GlobalScheduler::new(
        SchedulerConfig {
            solver: SolverKind::ExactMilp,
            milp_max_groups: 5,
            node_limit: 50_000,
            ..Default::default()
        },
        est,
    );
    // audit:allow(wall-clock): the measured quantity IS wall latency.
    let t0 = Instant::now();
    let a = sched.schedule(&small_refs, &views[..1], 0.0);
    let ms = 1000.0 * t0.elapsed().as_secs_f64();
    fig.row(vec![
        format!("{}", 5 * group_sz),
        "5".into(),
        "exact-milp".into(),
        f1(ms),
        f3(ms / 5.0),
    ]);
    let _ = a;
    fig.note(
        "paper Fig. 20: ~5 s per scheduling pass at 400K requests \
         (5 ms/request-group); greedy path scales linearly in groups",
    );
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_accuracy_improves_with_queue_size() {
        let (p1, a1) = wait_pairs(ModelId(1), 256, 1);
        let (p6, a6) = wait_pairs(ModelId(1), 1536, 1);
        let r2_small = r_squared(&p1, &a1);
        let r2_large = r_squared(&p6, &a6);
        assert!(
            r2_large > r2_small,
            "r2 large {r2_large} vs small {r2_small}"
        );
    }

    #[test]
    fn estimator_r2_high_for_long_queue() {
        let (p, a) = wait_pairs(ModelId(1), 1536, 60);
        let r2 = r_squared(&p, &a);
        assert!(r2 > 0.8, "R² = {r2}");
    }

    #[test]
    fn scheduler_scales_to_large_queues() {
        // 100K requests (390 groups) must schedule in well under a second.
        let f = fig20(Scale::Quick);
        let big = f
            .rows
            .iter()
            .find(|r| r[0] == "100000")
            .expect("100K row");
        let ms: f64 = big[3].parse().unwrap();
        assert!(ms < 5_000.0, "solve took {ms} ms");
    }
}
