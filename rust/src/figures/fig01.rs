//! Figure 1 (§1): prior SLO-oriented serving systems overestimate queue
//! waiting time, and the overestimate costs GPUs.
//!
//! Left: estimated vs actual waiting time at increasing queue depth —
//! QLM's RWT estimate (statistical, continuous batching) against a
//! Clockwork/SHEPHERD-style deterministic worst-case estimate (fixed
//! batches, max output length per request).
//!
//! Right: GPUs required to keep the 20 s p99 TTFT SLO, single- and
//! multi-model — found by sweeping fleet size under QLM vs SHEPHERD.

use crate::backend::{GpuKind, ModelCatalog, ModelId, PerfModel};
use crate::baselines::Policy;
use crate::coordinator::rwt::{ProfileTable, RwtEstimator};
use crate::figures::common::{f1, run_one, Figure, Scale};
use crate::figures::fig03::{dump_trace, wait_curve};
use crate::sim::fleet_a100;
use crate::workload::{SloClass, Trace, WorkloadSpec};

/// Deterministic worst-case wait estimate for position q — what systems
/// assuming fixed batches with deterministic execution times produce.
pub fn worst_case_wait(q: usize, perf: &PerfModel, max_out: f64, fixed_batch: u32) -> f64 {
    let batches_ahead = (q as f64 / fixed_batch as f64).ceil();
    batches_ahead * max_out * perf.epsilon * perf.decode_s_per_token + perf.prefill_s
}

/// Minimum fleet size (A100 instances) for ≥`target` interactive SLO
/// attainment on `trace` under `policy`.
pub fn gpus_required(trace: &Trace, policy: Policy, target: f64, max_fleet: u32) -> u32 {
    let catalog = ModelCatalog::paper_multi_model();
    for n in 1..=max_fleet {
        let m = run_one(trace, fleet_a100(n), catalog.clone(), policy);
        if m.slo_attainment() >= target {
            return n;
        }
    }
    max_fleet
}

pub fn run(scale: Scale) -> Figure {
    let mut fig = Figure::new(
        "fig01",
        "waiting-time overestimation and its GPU cost",
        &["panel", "x", "actual/qlm", "prior-systems"],
    );

    // ---- Left panel: estimate vs actual, Llama-70B standing queue. ----
    let model = ModelId(2);
    let n = scale.n(1000, 3000);
    let (_pos, meas, pred, _r2) = wait_curve(model, n, 5);
    let catalog = ModelCatalog::paper();
    let perf = PerfModel::profile(catalog.get(model), GpuKind::A100, 161.0);
    let trace = dump_trace(model, n, 5);
    let est = RwtEstimator::new(ProfileTable::from_trace(&trace));
    let profile = est.profiles.get(model, SloClass::Batch2, false);
    for q in (0..meas.len()).step_by((meas.len() / 6).max(1)) {
        let wc = worst_case_wait(q, &perf, profile.max_out, 16);
        fig.row(vec![
            "est-vs-actual".into(),
            format!("q={q}"),
            format!("{} / {}", f1(meas[q]), f1(pred[q])),
            f1(wc),
        ]);
    }
    let q_last = meas.len() - 1;
    let over = worst_case_wait(q_last, &perf, profile.max_out, 16) / meas[q_last].max(1e-9);
    fig.note(format!(
        "prior systems overestimate the queue drain by {:.1}× at q={} (paper Fig. 1-left shows the same gap)",
        over, q_last
    ));

    // ---- Right panel: GPUs to hold the 20 s TTFT SLO. ----
    let max_fleet = scale.n(8, 24) as u32;
    let reqs = scale.n(400, 3500);
    // Single model: interactive + batch on Mistral.
    let single = Trace::generate(
        &WorkloadSpec::w_a(ModelId(0), scale.f(60.0, 500.0), reqs),
        11,
    );
    // Multi model: same but over two models.
    let multi = Trace::generate(
        &WorkloadSpec::w_b(
            vec![ModelId(0), ModelId(1)],
            vec![ModelId(2), ModelId(1)],
            scale.f(60.0, 500.0),
            reqs,
        ),
        12,
    );
    for (name, trace) in [("single-model", &single), ("multi-model", &multi)] {
        let q = gpus_required(trace, Policy::qlm(), 0.95, max_fleet);
        let s = gpus_required(trace, Policy::Shepherd, 0.95, max_fleet);
        fig.row(vec![
            "gpus-required".into(),
            name.into(),
            format!("{q}"),
            format!("{s}"),
        ]);
    }
    fig.note("paper Fig. 1-right: QLM needs fewer GPUs, gap larger multi-model");
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worst_case_exceeds_statistical_estimate() {
        let catalog = ModelCatalog::paper();
        let perf = PerfModel::profile(catalog.get(ModelId(0)), GpuKind::A100, 161.0);
        let trace = dump_trace(ModelId(0), 200, 1);
        let est = RwtEstimator::new(ProfileTable::from_trace(&trace));
        let profile = est.profiles.get(ModelId(0), SloClass::Batch2, false);
        let q = 100;
        let wc = worst_case_wait(q, &perf, profile.max_out, 16);
        let (rwt, _) = est.request_wait(q, &perf, &profile);
        assert!(
            wc > 2.0 * rwt,
            "worst-case {wc} should dwarf statistical {rwt}"
        );
    }

    #[test]
    fn qlm_needs_no_more_gpus_than_shepherd() {
        let trace = Trace::generate(&WorkloadSpec::w_a(ModelId(0), 15.0, 250), 2);
        let q = gpus_required(&trace, Policy::qlm(), 0.9, 6);
        let s = gpus_required(&trace, Policy::Shepherd, 0.9, 6);
        assert!(q <= s, "qlm {q} vs shepherd {s}");
    }
}
