//! QLM: Queue Management for SLO-Oriented Large Language Model Serving.
//!
//! Reproduction of Patke et al., SoCC '24 (doi:10.1145/3698038.3698523) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * Layer 3 (this crate): the QLM coordinator — global queue, request
//!   groups, virtual queues, RWT estimator, global scheduler (MILP), and
//!   the four LLM Serving Operations (request pulling, request eviction,
//!   load balancing, model swapping) driving vLLM-like serving instances.
//! * Layer 2 (`python/compile/model.py`): a JAX transformer decode/prefill
//!   graph, AOT-lowered to HLO text loaded by [`runtime`].
//! * Layer 1 (`python/compile/kernels/`): Pallas paged-attention kernels
//!   (interpret mode) invoked from the Layer-2 graph.
//!
//! The default build is dependency-free and fully offline; the PJRT
//! runtime layer is gated behind the `pjrt` feature (see rust/Cargo.toml
//! and README.md "Real-model serving").

// Style allowances shared across the crate: the coordinator's callback
// signatures are long on purpose (the agent is decoupled from storage),
// and the hand-rolled subsystems keep explicit argument lists.
#![allow(clippy::too_many_arguments, clippy::type_complexity)]
// Unsafe operations stay explicit even inside `unsafe fn` bodies; the
// only unsafe code in the crate lives in util/pool.rs, and `qlm audit`
// (src/audit) enforces both that confinement and per-site SAFETY
// comments.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod audit;
pub mod util;
pub mod workload;
pub mod backend;
pub mod coordinator;
pub mod solver;
pub mod sim;
pub mod capacity;
pub mod baselines;
pub mod metrics;
pub mod obs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod figures;
