//! Synthetic ShareGPT sampler.
//!
//! The paper (Fig. 8) evaluates on 3,500 requests from the ShareGPT_Vicuna
//! dataset. We cannot ship the dataset, so we fit the published input /
//! output token histograms: both are heavy-tailed, well approximated by
//! log-normal distributions truncated to [1, 4096]:
//!
//! * input tokens:  median ≈ 70,  mean ≈ 161, long tail to 4k
//! * output tokens: median ≈ 255, mean ≈ 338, tail to 2k
//!
//! These match the first two moments and the tail mass that drive the RWT
//! estimator (which consumes only μ_o, σ_o per request group), so the
//! substitution preserves the queueing behaviour the paper studies
//! (DESIGN.md §Substitutions).

use crate::util::Rng;

/// Log-normal parameters for a token-length distribution.
#[derive(Debug, Clone, Copy)]
pub struct TokenDist {
    /// Underlying normal mean (of ln tokens).
    pub mu: f64,
    /// Underlying normal stddev.
    pub sigma: f64,
    /// Inclusive clamp range.
    pub min: u32,
    pub max: u32,
}

impl TokenDist {
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let v = rng.lognormal(self.mu, self.sigma).round();
        (v as u32).clamp(self.min, self.max)
    }

    /// Analytic mean of the *untruncated* log-normal (used for sanity
    /// checks; empirical moments are measured from samples).
    pub fn analytic_mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp()
    }
}

/// ShareGPT-shaped sampler for (input_tokens, output_tokens).
#[derive(Debug, Clone)]
pub struct ShareGptSampler {
    pub input: TokenDist,
    pub output: TokenDist,
}

impl Default for ShareGptSampler {
    fn default() -> Self {
        // ln-space parameters fitted to the Fig. 8 histograms.
        Self {
            input: TokenDist {
                mu: 4.25,   // median ≈ 70
                sigma: 1.15, // mean ≈ 136, p99 ≈ 1k+
                min: 4,
                max: 4096,
            },
            output: TokenDist {
                mu: 5.45,   // median ≈ 233
                sigma: 0.85, // mean ≈ 333
                min: 4,
                max: 2048,
            },
        }
    }
}

impl ShareGptSampler {
    /// Sampler restricted to "mega prompts" (workload W_C): total tokens in
    /// the 3K–4K range, rejection-sampled from the tail.
    pub fn mega_prompt(&self, rng: &mut Rng) -> (u32, u32) {
        loop {
            // Bias the draw upward, then accept on the 3K–4K window.
            let i = rng.range(1200.0, 3000.0) as u32;
            let o = rng.range(500.0, 2000.0) as u32;
            let total = i + o;
            if (3000..=4000).contains(&total) {
                return (i, o);
            }
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> (u32, u32) {
        (self.input.sample(rng), self.output.sample(rng))
    }

    /// Empirical (mean, std) of output tokens over `n` draws — what QLM's
    /// workload profiling step (§6, Offline Profiling) produces.
    pub fn profile_output(&self, n: usize, rng: &mut Rng) -> (f64, f64) {
        let xs: Vec<f64> = (0..n).map(|_| self.output.sample(rng) as f64).collect();
        (crate::util::mean(&xs), crate::util::stddev(&xs))
    }

    /// Empirical (mean, std) of input tokens.
    pub fn profile_input(&self, n: usize, rng: &mut Rng) -> (f64, f64) {
        let xs: Vec<f64> = (0..n).map(|_| self.input.sample(rng) as f64).collect();
        (crate::util::mean(&xs), crate::util::stddev(&xs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_within_clamp() {
        let s = ShareGptSampler::default();
        let mut rng = Rng::new(1);
        for _ in 0..5_000 {
            let (i, o) = s.sample(&mut rng);
            assert!((4..=4096).contains(&i));
            assert!((4..=2048).contains(&o));
        }
    }

    #[test]
    fn moments_match_fig8_shape() {
        let s = ShareGptSampler::default();
        let mut rng = Rng::new(2);
        let (mi, _) = s.profile_input(50_000, &mut rng);
        let (mo, so) = s.profile_output(50_000, &mut rng);
        // Fig. 8 / ShareGPT: mean input ~100-200, mean output ~250-400.
        assert!((100.0..220.0).contains(&mi), "input mean {mi}");
        assert!((250.0..420.0).contains(&mo), "output mean {mo}");
        assert!(so > 100.0, "output heavy tail, std {so}");
    }

    #[test]
    fn output_right_skewed() {
        let s = ShareGptSampler::default();
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| s.output.sample(&mut rng) as f64)
            .collect();
        let mean = crate::util::mean(&xs);
        let median = crate::util::percentile(&xs, 50.0);
        assert!(mean > median, "right skew: mean {mean} median {median}");
    }

    #[test]
    fn mega_prompts_in_3k_4k_window() {
        let s = ShareGptSampler::default();
        let mut rng = Rng::new(4);
        for _ in 0..500 {
            let (i, o) = s.mega_prompt(&mut rng);
            let t = i + o;
            assert!((3000..=4000).contains(&t), "total {t}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = ShareGptSampler::default();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..100 {
            assert_eq!(s.sample(&mut a), s.sample(&mut b));
        }
    }
}
