//! Workload specifications: SLO classes and the paper's evaluation
//! workloads W_A (single-model interactive+batch), W_B (multi-model
//! batch), W_C (mega-prompt) — §8, "Workloads".

use crate::backend::ModelId;
use crate::workload::{ArrivalProcess, ShareGptSampler};

/// A two-dimensional latency SLO: a time-to-first-token bound plus a
/// time-per-output-token bound. TTFT is what queue ordering fights for
/// (the paper's headline metric); TPOT is what decode-time interference
/// — chunked prefill mixed into the batch, evictions, model swaps —
/// erodes. Both must hold for a request to count as SLO-met.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    /// p99 TTFT bound, seconds.
    pub ttft_s: f64,
    /// Mean inter-token latency bound, seconds per output token.
    pub tpot_s: f64,
}

impl SloTarget {
    pub const fn new(ttft_s: f64, tpot_s: f64) -> Self {
        SloTarget { ttft_s, tpot_s }
    }

    /// Component-wise minimum — the binding constraint of a set of
    /// requests (used when folding members into a group SLO).
    pub fn min(self, other: SloTarget) -> SloTarget {
        SloTarget {
            ttft_s: self.ttft_s.min(other.ttft_s),
            tpot_s: self.tpot_s.min(other.tpot_s),
        }
    }
}

/// The three request categories of §8, with p99-TTFT SLOs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SloClass {
    /// Chatbot-style: p99 TTFT < 20 s.
    Interactive,
    /// Relaxed batch: 1 minute.
    Batch1,
    /// Very relaxed batch: 1 hour.
    Batch2,
}

impl SloClass {
    /// Every class, tightest SLO first (deadline priority order).
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Batch1, SloClass::Batch2];

    /// Dense index (position in [`Self::ALL`]) for per-class tables.
    pub fn index(&self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Batch1 => 1,
            SloClass::Batch2 => 2,
        }
    }

    /// The class's SLO target. TTFT bounds are the paper's §8 values;
    /// TPOT bounds scale with the class's latency tolerance (decode
    /// stalls from eviction/requeue cycles are what they police).
    pub fn target(&self) -> SloTarget {
        match self {
            SloClass::Interactive => SloTarget::new(20.0, 0.25),
            SloClass::Batch1 => SloTarget::new(60.0, 1.0),
            SloClass::Batch2 => SloTarget::new(3600.0, 10.0),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch1 => "batch-1",
            SloClass::Batch2 => "batch-2",
        }
    }
}

/// One stream of requests: a class, the models it targets (uniformly
/// chosen), an arrival process, and how many requests it contributes.
#[derive(Debug, Clone)]
pub struct RequestClassSpec {
    pub class: SloClass,
    pub models: Vec<ModelId>,
    pub arrivals: ArrivalProcess,
    pub count: usize,
    /// Fraction of this stream drawn from the mega-prompt sampler (W_C).
    pub mega_fraction: f64,
}

/// A full workload: several request streams sharing a token sampler.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub streams: Vec<RequestClassSpec>,
    pub sampler: ShareGptSampler,
}

impl WorkloadSpec {
    /// W_A: single-model interactive + batch (paper §8). `rate` is the
    /// interactive arrival rate (requests/s); batch streams arrive at a
    /// fixed fraction of it. Total requests ≈ `total` split 50/25/25.
    pub fn w_a(model: ModelId, interactive_rate: f64, total: usize) -> Self {
        let n_i = total / 2;
        let n_b = total / 4;
        WorkloadSpec {
            name: format!("W_A(rate={interactive_rate})"),
            streams: vec![
                RequestClassSpec {
                    class: SloClass::Interactive,
                    models: vec![model],
                    arrivals: ArrivalProcess::Poisson {
                        rate: interactive_rate,
                    },
                    count: n_i,
                    mega_fraction: 0.0,
                },
                RequestClassSpec {
                    class: SloClass::Batch1,
                    models: vec![model],
                    arrivals: ArrivalProcess::Poisson {
                        rate: interactive_rate * 0.5,
                    },
                    count: n_b,
                    mega_fraction: 0.0,
                },
                RequestClassSpec {
                    class: SloClass::Batch2,
                    models: vec![model],
                    arrivals: ArrivalProcess::Poisson {
                        rate: interactive_rate * 0.5,
                    },
                    count: n_b,
                    mega_fraction: 0.0,
                },
            ],
            sampler: ShareGptSampler::default(),
        }
    }

    /// W_B: multi-model batch workload. Batch-1 over `b1_models`
    /// (fine-tuned Mistral-7B and Llama-70B in the paper), Batch-2 over
    /// `b2_models` (fine-tuned Vicuna-13B and Llama-70B). `b1_rate` is the
    /// swept Batch-1 arrival rate.
    pub fn w_b(
        b1_models: Vec<ModelId>,
        b2_models: Vec<ModelId>,
        b1_rate: f64,
        total: usize,
    ) -> Self {
        let n = total / 2;
        WorkloadSpec {
            name: format!("W_B(b1_rate={b1_rate})"),
            streams: vec![
                RequestClassSpec {
                    class: SloClass::Batch1,
                    models: b1_models,
                    arrivals: ArrivalProcess::Poisson { rate: b1_rate },
                    count: n,
                    mega_fraction: 0.0,
                },
                RequestClassSpec {
                    class: SloClass::Batch2,
                    models: b2_models,
                    arrivals: ArrivalProcess::Poisson { rate: b1_rate * 0.5 },
                    count: total - n,
                    mega_fraction: 0.0,
                },
            ],
            sampler: ShareGptSampler::default(),
        }
    }

    /// W_C: W_B plus a fraction of mega prompts (3K–4K total tokens).
    pub fn w_c(
        b1_models: Vec<ModelId>,
        b2_models: Vec<ModelId>,
        b1_rate: f64,
        total: usize,
        mega_fraction: f64,
    ) -> Self {
        let mut w = Self::w_b(b1_models, b2_models, b1_rate, total);
        w.name = format!("W_C(mega={mega_fraction})");
        for s in &mut w.streams {
            s.mega_fraction = mega_fraction;
        }
        w
    }

    pub fn total_requests(&self) -> usize {
        self.streams.iter().map(|s| s.count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_values_match_paper() {
        assert_eq!(SloClass::Interactive.target().ttft_s, 20.0);
        assert_eq!(SloClass::Batch1.target().ttft_s, 60.0);
        assert_eq!(SloClass::Batch2.target().ttft_s, 3600.0);
        // TPOT bounds loosen with the class's latency tolerance.
        assert!(SloClass::Interactive.target().tpot_s < SloClass::Batch1.target().tpot_s);
        assert!(SloClass::Batch1.target().tpot_s < SloClass::Batch2.target().tpot_s);
    }

    #[test]
    fn slo_target_min_is_componentwise() {
        let a = SloTarget::new(20.0, 1.0);
        let b = SloTarget::new(60.0, 0.25);
        let m = a.min(b);
        assert_eq!(m, SloTarget::new(20.0, 0.25));
    }

    #[test]
    fn w_a_is_single_model_three_classes() {
        let w = WorkloadSpec::w_a(ModelId(0), 100.0, 3500);
        assert_eq!(w.streams.len(), 3);
        assert!(w
            .streams
            .iter()
            .all(|s| s.models == vec![ModelId(0)]));
        assert!(w.total_requests() >= 3400);
    }

    #[test]
    fn w_b_two_batch_classes() {
        let w = WorkloadSpec::w_b(
            vec![ModelId(0), ModelId(1)],
            vec![ModelId(2), ModelId(1)],
            250.0,
            3500,
        );
        assert_eq!(w.streams.len(), 2);
        assert!(w.streams.iter().all(|s| s.class != SloClass::Interactive));
        assert_eq!(w.total_requests(), 3500);
    }

    #[test]
    fn w_c_sets_mega_fraction() {
        let w = WorkloadSpec::w_c(vec![ModelId(0)], vec![ModelId(1)], 100.0, 1000, 0.1);
        assert!(w.streams.iter().all(|s| (s.mega_fraction - 0.1).abs() < 1e-12));
    }
}
