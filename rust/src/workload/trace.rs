//! Materialized request traces: a `WorkloadSpec` is expanded into a
//! time-sorted list of concrete requests with ground-truth token counts.
//! The ground-truth output length is visible to the simulator only — the
//! coordinator's RWT estimator sees just per-group distributions, exactly
//! as in the paper (§6: output tokens are unknown a priori).

use crate::backend::ModelId;
use crate::workload::stream::ArrivalStream;
use crate::workload::{SloClass, SloTarget, WorkloadSpec};

/// A single concrete request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub arrival_s: f64,
    pub model: ModelId,
    pub class: SloClass,
    /// TTFT + TPOT bounds (the class target at generation time).
    pub slo: SloTarget,
    pub input_tokens: u32,
    /// Ground truth — hidden from the estimator.
    pub output_tokens: u32,
    pub mega: bool,
}

/// A materialized workload trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Expand a spec into a concrete trace. Deterministic given `seed`.
    ///
    /// Defined as a collect over [`ArrivalStream`], so a streamed run
    /// (which never materializes this Vec) sees byte-identical requests.
    pub fn generate(spec: &WorkloadSpec, seed: u64) -> Trace {
        let mut requests = Vec::with_capacity(spec.total_requests());
        requests.extend(ArrivalStream::new(spec, seed));
        Trace {
            name: spec.name.clone(),
            requests,
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Distinct models referenced by the trace.
    pub fn models(&self) -> Vec<ModelId> {
        let mut ms: Vec<ModelId> = self.requests.iter().map(|r| r.model).collect();
        ms.sort();
        ms.dedup();
        ms
    }

    /// Mean output tokens — used by tests and figure harnesses.
    pub fn mean_output_tokens(&self) -> f64 {
        crate::util::mean(
            &self
                .requests
                .iter()
                .map(|r| r.output_tokens as f64)
                .collect::<Vec<_>>(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_sorted_and_complete() {
        let spec = WorkloadSpec::w_a(ModelId(0), 50.0, 2000);
        let t = Trace::generate(&spec, 7);
        assert_eq!(t.len(), spec.total_requests());
        assert!(t
            .requests
            .windows(2)
            .all(|w| w[1].arrival_s >= w[0].arrival_s));
    }

    #[test]
    fn deterministic_by_seed() {
        let spec = WorkloadSpec::w_a(ModelId(0), 50.0, 500);
        let a = Trace::generate(&spec, 1);
        let b = Trace::generate(&spec, 1);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.input_tokens, y.input_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
        let c = Trace::generate(&spec, 2);
        assert!(a
            .requests
            .iter()
            .zip(&c.requests)
            .any(|(x, y)| x.input_tokens != y.input_tokens));
    }

    #[test]
    fn multi_model_trace_uses_all_models() {
        let spec = WorkloadSpec::w_b(
            vec![ModelId(0), ModelId(1)],
            vec![ModelId(2), ModelId(1)],
            100.0,
            2000,
        );
        let t = Trace::generate(&spec, 3);
        assert_eq!(t.models(), vec![ModelId(0), ModelId(1), ModelId(2)]);
    }

    #[test]
    fn mega_fraction_respected() {
        let spec = WorkloadSpec::w_c(vec![ModelId(0)], vec![ModelId(0)], 100.0, 4000, 0.25);
        let t = Trace::generate(&spec, 4);
        let mega = t.requests.iter().filter(|r| r.mega).count() as f64 / t.len() as f64;
        assert!((mega - 0.25).abs() < 0.05, "mega frac {mega}");
        assert!(t
            .requests
            .iter()
            .filter(|r| r.mega)
            .all(|r| (3000..=4000).contains(&(r.input_tokens + r.output_tokens))));
    }

    #[test]
    fn slo_matches_class() {
        let spec = WorkloadSpec::w_a(ModelId(0), 10.0, 300);
        let t = Trace::generate(&spec, 5);
        assert!(t.requests.iter().all(|r| r.slo == r.class.target()));
    }
}
