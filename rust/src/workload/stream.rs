//! Pull-based trace generation: an [`ArrivalStream`] expands a
//! `WorkloadSpec` one request at a time, in global arrival order,
//! without ever materializing the full trace. A 10M-request run holds
//! O(streams) generator state instead of a multi-GB `Vec<TraceRequest>`.
//!
//! Determinism contract: [`crate::workload::Trace::generate`] is defined
//! as `ArrivalStream::new(spec, seed).collect()`, so a streamed run and a
//! materialized run of the same `(spec, seed)` see byte-identical request
//! sequences *by construction*. The merge reproduces what
//! `sort_by(arrival_s)` (a stable sort over stream-major generation
//! order) produces: each stream's arrivals are monotone non-decreasing,
//! so a k-way head merge that takes the strictly-smallest head and
//! breaks ties by lowest stream index yields exactly the stable-sorted
//! order.

use crate::backend::ModelId;
use crate::util::Rng;
use crate::workload::arrivals::Arrivals;
use crate::workload::{ShareGptSampler, SloClass, SloTarget, TraceRequest, WorkloadSpec};

/// Generator state for one request stream of the spec.
#[derive(Debug, Clone)]
struct StreamState {
    class: SloClass,
    slo: SloTarget,
    models: Vec<ModelId>,
    mega_fraction: f64,
    arrivals: Arrivals,
    /// Per-stream RNG, forked from the seed in stream order, so one
    /// stream's draw count never perturbs another stream's values.
    rng: Rng,
    /// Requests this stream has yet to emit (its head excluded).
    left: usize,
}

/// A seeded, deterministic iterator over the spec's requests in global
/// arrival order. `peek_t` exposes the next arrival time without
/// consuming it, which is what lets the sim's timer wheel interleave
/// generated arrivals with runtime events.
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    sampler: ShareGptSampler,
    streams: Vec<StreamState>,
    /// One primed head per stream (`None` once the stream is dry).
    heads: Vec<Option<TraceRequest>>,
    remaining: usize,
}

impl ArrivalStream {
    /// Build the stream for `spec`, deterministically from `seed`.
    pub fn new(spec: &WorkloadSpec, seed: u64) -> ArrivalStream {
        let mut base = Rng::new(seed);
        let streams: Vec<StreamState> = spec
            .streams
            .iter()
            .map(|s| StreamState {
                class: s.class,
                slo: s.class.target(),
                models: s.models.clone(),
                mega_fraction: s.mega_fraction,
                arrivals: Arrivals::new(s.arrivals),
                rng: base.fork(),
                left: s.count,
            })
            .collect();
        let mut stream = ArrivalStream {
            sampler: spec.sampler.clone(),
            heads: vec![None; streams.len()],
            remaining: streams.iter().map(|s| s.left).sum(),
            streams,
        };
        for i in 0..stream.streams.len() {
            stream.refill(i);
        }
        stream
    }

    /// Draw the next request of stream `i` into its head slot. The
    /// per-request draw order (arrival, mega coin, tokens, model) is the
    /// same sequence `Trace::generate` has always used.
    fn refill(&mut self, i: usize) {
        let s = &mut self.streams[i];
        self.heads[i] = if s.left == 0 {
            None
        } else {
            s.left -= 1;
            let arrival_s = s.arrivals.next(&mut s.rng);
            let mega = s.rng.f64() < s.mega_fraction;
            let (input_tokens, output_tokens) = if mega {
                self.sampler.mega_prompt(&mut s.rng)
            } else {
                self.sampler.sample(&mut s.rng)
            };
            let model = *s.rng.choose(&s.models);
            Some(TraceRequest {
                arrival_s,
                model,
                class: s.class,
                slo: s.slo,
                input_tokens,
                output_tokens,
                mega,
            })
        };
    }

    /// Index of the head with the smallest arrival time; ties go to the
    /// lowest stream index (the stable-sort tiebreak).
    fn best_head(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, head) in self.heads.iter().enumerate() {
            let Some(r) = head else { continue };
            match best {
                None => best = Some(i),
                Some(b) => {
                    let bt = match &self.heads[b] {
                        Some(h) => h.arrival_s,
                        None => f64::INFINITY,
                    };
                    if r.arrival_s < bt {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// Arrival time of the next request, without consuming it.
    pub fn peek_t(&self) -> Option<f64> {
        self.best_head()
            .and_then(|i| self.heads[i].as_ref().map(|r| r.arrival_s))
    }

    /// Requests not yet emitted.
    pub fn remaining(&self) -> usize {
        self.remaining
    }
}

impl Iterator for ArrivalStream {
    type Item = TraceRequest;

    fn next(&mut self) -> Option<TraceRequest> {
        let i = self.best_head()?;
        let req = self.heads[i].take();
        self.refill(i);
        self.remaining -= 1;
        req
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for ArrivalStream {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    #[test]
    fn streamed_equals_materialized() {
        let spec = WorkloadSpec::w_b(
            vec![ModelId(0), ModelId(1)],
            vec![ModelId(2), ModelId(1)],
            80.0,
            3000,
        );
        let trace = Trace::generate(&spec, 11);
        let streamed: Vec<TraceRequest> = ArrivalStream::new(&spec, 11).collect();
        assert_eq!(streamed.len(), trace.len());
        for (a, b) in streamed.iter().zip(&trace.requests) {
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.model, b.model);
            assert_eq!(a.class, b.class);
            assert_eq!(a.input_tokens, b.input_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.mega, b.mega);
        }
    }

    #[test]
    fn emits_in_sorted_order_with_exact_count() {
        let spec = WorkloadSpec::w_a(ModelId(0), 40.0, 2000);
        let mut stream = ArrivalStream::new(&spec, 5);
        assert_eq!(stream.len(), spec.total_requests());
        let mut last = f64::NEG_INFINITY;
        let mut n = 0usize;
        while let Some(r) = stream.next() {
            assert!(r.arrival_s >= last, "stream must be time-sorted");
            last = r.arrival_s;
            n += 1;
        }
        assert_eq!(n, spec.total_requests());
        assert_eq!(stream.remaining(), 0);
    }

    #[test]
    fn peek_matches_next() {
        let spec = WorkloadSpec::w_a(ModelId(0), 25.0, 500);
        let mut stream = ArrivalStream::new(&spec, 9);
        while let Some(t) = stream.peek_t() {
            let r = stream.next().expect("peek implies next");
            assert_eq!(r.arrival_s, t);
        }
        assert!(stream.next().is_none());
        assert!(stream.peek_t().is_none());
    }

    #[test]
    fn replay_from_seed_is_reproducible() {
        let spec = WorkloadSpec::w_c(vec![ModelId(0)], vec![ModelId(1)], 60.0, 1200, 0.2);
        let a: Vec<TraceRequest> = ArrivalStream::new(&spec, 3).collect();
        let b: Vec<TraceRequest> = ArrivalStream::new(&spec, 3).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.input_tokens, y.input_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
    }

    #[test]
    fn dump_streams_tie_break_by_stream_index() {
        // Two Dump streams: every arrival is t=0, so the merge order is
        // purely the stable tiebreak — all of stream 0, then stream 1.
        let spec = WorkloadSpec {
            name: "ties".to_string(),
            streams: vec![
                crate::workload::RequestClassSpec {
                    class: SloClass::Interactive,
                    models: vec![ModelId(0)],
                    arrivals: crate::workload::ArrivalProcess::Dump,
                    count: 5,
                    mega_fraction: 0.0,
                },
                crate::workload::RequestClassSpec {
                    class: SloClass::Batch1,
                    models: vec![ModelId(1)],
                    arrivals: crate::workload::ArrivalProcess::Dump,
                    count: 5,
                    mega_fraction: 0.0,
                },
            ],
            sampler: ShareGptSampler::default(),
        };
        let reqs: Vec<TraceRequest> = ArrivalStream::new(&spec, 1).collect();
        assert_eq!(reqs.len(), 10);
        assert!(reqs[..5].iter().all(|r| r.class == SloClass::Interactive));
        assert!(reqs[5..].iter().all(|r| r.class == SloClass::Batch1));
    }
}
