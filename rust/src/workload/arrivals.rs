//! Arrival processes. The paper models request arrivals as Poisson (§8,
//! Workloads) and additionally studies burstiness (§8.3); we provide
//! Poisson, Gamma-modulated (bursty), and closed-loop batch-dump arrivals.

use crate::util::Rng;

/// Kinds of arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate` requests/second.
    Poisson { rate: f64 },
    /// Markov-modulated Poisson: alternates between a quiet and a burst
    /// phase; `burstiness` ≥ 1 scales the burst-phase rate.
    Bursty {
        rate: f64,
        burstiness: f64,
        phase_len_s: f64,
    },
    /// All requests arrive at t=0 — the "drain a standing queue" setup
    /// used by Fig. 5 / Fig. 17 style experiments.
    Dump,
    /// Fixed inter-arrival gap (deterministic) — used by unit tests.
    Uniform { rate: f64 },
    /// Nonhomogeneous Poisson with a sinusoidal day/night profile:
    /// rate(t) = base + (peak − base) · ½(1 − cos 2πt/period), sampled
    /// by Lewis–Shedler thinning. Drives the `diurnal` CLI scenario.
    Diurnal {
        base_rate: f64,
        peak_rate: f64,
        period_s: f64,
    },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate (req/s); `None` for `Dump`, whose
    /// instantaneous rate is unbounded. Consumed by the capacity
    /// planner's throughput sizing.
    pub fn mean_rate(&self) -> Option<f64> {
        Some(match self {
            ArrivalProcess::Poisson { rate } | ArrivalProcess::Uniform { rate } => *rate,
            ArrivalProcess::Bursty { rate, burstiness, .. } => {
                // Phases alternate evenly: average the burst-phase rate
                // with the residual quiet-phase rate (see `next`).
                let quiet = (rate * (2.0 - burstiness)).max(rate * 0.05);
                0.5 * (rate * burstiness + quiet)
            }
            ArrivalProcess::Diurnal { base_rate, peak_rate, .. } => 0.5 * (base_rate + peak_rate),
            ArrivalProcess::Dump => return None,
        })
    }

    /// Peak sustained arrival rate (req/s); `None` for `Dump`. Consumed
    /// by the capacity planner's latency-bound-class sizing.
    pub fn peak_rate(&self) -> Option<f64> {
        Some(match self {
            ArrivalProcess::Poisson { rate } | ArrivalProcess::Uniform { rate } => *rate,
            ArrivalProcess::Bursty { rate, burstiness, .. } => rate * burstiness.max(1.0),
            ArrivalProcess::Diurnal { peak_rate, .. } => *peak_rate,
            ArrivalProcess::Dump => return None,
        })
    }
}

/// Stateful arrival-time generator.
#[derive(Debug, Clone)]
pub struct Arrivals {
    process: ArrivalProcess,
    now: f64,
    /// For Bursty: true if currently in the burst phase.
    in_burst: bool,
    phase_left: f64,
}

impl Arrivals {
    pub fn new(process: ArrivalProcess) -> Self {
        let phase_left = match process {
            ArrivalProcess::Bursty { phase_len_s, .. } => phase_len_s,
            _ => 0.0,
        };
        Self {
            process,
            now: 0.0,
            in_burst: false,
            phase_left,
        }
    }

    /// Next arrival timestamp (seconds since epoch 0), monotone
    /// non-decreasing.
    pub fn next(&mut self, rng: &mut Rng) -> f64 {
        match self.process {
            ArrivalProcess::Poisson { rate } => {
                self.now += rng.exponential(rate.max(1e-9));
            }
            ArrivalProcess::Uniform { rate } => {
                self.now += 1.0 / rate.max(1e-9);
            }
            ArrivalProcess::Dump => { /* all at t = 0 */ }
            ArrivalProcess::Diurnal {
                base_rate,
                peak_rate,
                period_s,
            } => {
                // Thinning: propose at the envelope rate, accept with
                // probability rate(t)/envelope.
                let envelope = peak_rate.max(base_rate).max(1e-9);
                loop {
                    self.now += rng.exponential(envelope);
                    let phase = self.now / period_s.max(1e-9) * std::f64::consts::TAU;
                    let rate = base_rate
                        + (peak_rate - base_rate).max(0.0) * 0.5 * (1.0 - phase.cos());
                    if rng.f64() * envelope <= rate {
                        break;
                    }
                }
            }
            ArrivalProcess::Bursty {
                rate,
                burstiness,
                phase_len_s,
            } => {
                let eff_rate = if self.in_burst {
                    rate * burstiness
                } else {
                    // Keep the long-run average at `rate`: quiet phase gets
                    // the residual rate 2r - r*b, floored at 5% of r.
                    (rate * (2.0 - burstiness)).max(rate * 0.05)
                };
                let gap = rng.exponential(eff_rate.max(1e-9));
                self.now += gap;
                self.phase_left -= gap;
                if self.phase_left <= 0.0 {
                    self.in_burst = !self.in_burst;
                    self.phase_left = phase_len_s;
                }
            }
        }
        self.now
    }

    /// Generate `n` arrival timestamps.
    pub fn take(&mut self, n: usize, rng: &mut Rng) -> Vec<f64> {
        (0..n).map(|_| self.next(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mean;

    #[test]
    fn poisson_rate_matches() {
        let mut a = Arrivals::new(ArrivalProcess::Poisson { rate: 100.0 });
        let mut rng = Rng::new(1);
        let ts = a.take(50_000, &mut rng);
        let horizon = *ts.last().unwrap();
        let measured = ts.len() as f64 / horizon;
        assert!((measured - 100.0).abs() / 100.0 < 0.05, "rate {measured}");
    }

    #[test]
    fn arrivals_monotone() {
        for p in [
            ArrivalProcess::Poisson { rate: 10.0 },
            ArrivalProcess::Bursty {
                rate: 10.0,
                burstiness: 5.0,
                phase_len_s: 1.0,
            },
            ArrivalProcess::Uniform { rate: 10.0 },
        ] {
            let mut a = Arrivals::new(p);
            let mut rng = Rng::new(2);
            let ts = a.take(1_000, &mut rng);
            assert!(ts.windows(2).all(|w| w[1] >= w[0]));
        }
    }

    #[test]
    fn dump_all_zero() {
        let mut a = Arrivals::new(ArrivalProcess::Dump);
        let mut rng = Rng::new(3);
        assert!(a.take(100, &mut rng).iter().all(|&t| t == 0.0));
    }

    #[test]
    fn bursty_has_higher_cv_than_poisson() {
        let mut rng = Rng::new(4);
        let gaps = |p: ArrivalProcess, rng: &mut Rng| -> Vec<f64> {
            let mut a = Arrivals::new(p);
            let ts = a.take(20_000, rng);
            ts.windows(2).map(|w| w[1] - w[0]).collect()
        };
        let pg = gaps(ArrivalProcess::Poisson { rate: 50.0 }, &mut rng);
        let bg = gaps(
            ArrivalProcess::Bursty {
                rate: 50.0,
                burstiness: 8.0,
                phase_len_s: 2.0,
            },
            &mut rng,
        );
        let cv = |g: &[f64]| crate::util::stddev(g) / mean(g);
        assert!(cv(&bg) > cv(&pg) * 1.1, "cv_burst={} cv_poisson={}", cv(&bg), cv(&pg));
    }

    #[test]
    fn diurnal_peaks_mid_period() {
        let mut a = Arrivals::new(ArrivalProcess::Diurnal {
            base_rate: 2.0,
            peak_rate: 60.0,
            period_s: 100.0,
        });
        let mut rng = Rng::new(6);
        let mut mid = 0usize;
        let mut edge = 0usize;
        loop {
            let t = a.next(&mut rng);
            if t >= 100.0 {
                break;
            }
            let phase = t % 100.0;
            if (25.0..75.0).contains(&phase) {
                mid += 1;
            } else {
                edge += 1;
            }
        }
        assert!(
            mid > edge * 2,
            "diurnal peak not centered: mid={mid} edge={edge}"
        );
    }

    #[test]
    fn diurnal_monotone() {
        let mut a = Arrivals::new(ArrivalProcess::Diurnal {
            base_rate: 1.0,
            peak_rate: 10.0,
            period_s: 50.0,
        });
        let mut rng = Rng::new(7);
        let ts = a.take(2_000, &mut rng);
        assert!(ts.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn rate_moments_match_process() {
        assert_eq!(
            ArrivalProcess::Poisson { rate: 8.0 }.mean_rate(),
            Some(8.0)
        );
        assert_eq!(
            ArrivalProcess::Poisson { rate: 8.0 }.peak_rate(),
            Some(8.0)
        );
        let d = ArrivalProcess::Diurnal {
            base_rate: 4.0,
            peak_rate: 16.0,
            period_s: 100.0,
        };
        assert_eq!(d.mean_rate(), Some(10.0));
        assert_eq!(d.peak_rate(), Some(16.0));
        let b = ArrivalProcess::Bursty {
            rate: 10.0,
            burstiness: 6.0,
            phase_len_s: 1.0,
        };
        assert_eq!(b.peak_rate(), Some(60.0));
        // Mean stays near the headline rate (quiet floor pulls it up a bit).
        assert!(b.mean_rate().unwrap() >= 10.0);
        assert!(ArrivalProcess::Dump.mean_rate().is_none());
        assert!(ArrivalProcess::Dump.peak_rate().is_none());
    }

    #[test]
    fn uniform_gap_exact() {
        let mut a = Arrivals::new(ArrivalProcess::Uniform { rate: 4.0 });
        let mut rng = Rng::new(5);
        let ts = a.take(4, &mut rng);
        assert!((ts[3] - 1.0).abs() < 1e-12);
    }
}
