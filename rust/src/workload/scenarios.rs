//! Scenario catalog for the `qlm sim --scenario <name>` CLI: one named
//! entry per paper regime, so a single command reproduces each evaluation
//! setting — §8's mixed batch/interactive traffic, heterogeneous
//! multi-model serving, bursty and diurnal arrival patterns, and §4's
//! instance-failure fault tolerance.
//!
//! A scenario expands a small set of knobs (rate, request count, fleet
//! size, seed) into everything a simulation run needs: model catalog,
//! workload spec, fleet, and any injected failures.

use crate::backend::{GpuKind, InstanceConfig, InstanceId, ModelCatalog, ModelId};
use crate::baselines::Policy;
use crate::capacity::{AdmissionConfig, AutoscaleConfig};
use crate::sim::{fleet_a100, fleet_mixed, fleet_of, SimConfig};
use crate::workload::{ArrivalProcess, RequestClassSpec, ShareGptSampler, SloClass, WorkloadSpec};

/// Named workload scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Markov-modulated bursts of interactive traffic over a batch floor.
    Burst,
    /// Day/night sinusoidal interactive rate over a batch floor.
    Diurnal,
    /// The paper's W_A: interactive + two batch classes, one model.
    MixedSlo,
    /// The paper's W_B: fine-tuned model variants multiplexed on a
    /// shared fleet (model swapping dominates).
    MultiModel,
    /// Mixed traffic with an instance failure injected mid-run (§4).
    Failover,
    /// Fig. 20's overhead regime as a live run: 100K+ queued requests,
    /// mixed SLO classes across multiple models, incremental scheduler.
    Scale,
    /// Capacity-subsystem showcase: diurnal arrivals over a 4× peak-to-
    /// trough swing, mixed SLO classes on multiple models, a trough-
    /// sized starting fleet, and the runtime autoscaler + admission
    /// control riding the wave.
    Autoscale,
    /// Long-prompt stress: W_A-style mixed-SLO traffic on Vicuna-13B
    /// with a heavy mega-prompt fraction on the batch streams — the
    /// regime where whole-request prefill stalls interactive first
    /// tokens behind multi-thousand-token prompts (the chunked-prefill
    /// policy's showcase).
    Mega,
    /// The scale shape at a million-plus queued requests: the hot-path
    /// gate for the timer-wheel event core, arena request storage, and
    /// the work-stealing lanes. Same mixed-SLO multi-model streams as
    /// `scale`, sized an order of magnitude past Fig. 20.
    Megascale,
    /// The 10M-request gate: the scale shape another order of magnitude
    /// up, runnable only through the streamed-arrival path (`qlm sim
    /// --stream` / `Simulation::new_streaming`) with compact records —
    /// the trace is never materialized, so resident memory stays
    /// O(in-flight) while the sharded broker absorbs the multi-model
    /// churn. The CI wall-clock + peak-alloc gate for this PR's sharded
    /// queue and streaming generation runs here.
    Gigascale,
}

/// Tunable knobs shared by every scenario.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioKnobs {
    /// Headline arrival rate, requests/second (scenario-dependent use).
    pub rate: f64,
    /// Total requests in the trace.
    pub requests: usize,
    /// Instance count.
    pub fleet: u32,
    pub seed: u64,
}

impl Default for ScenarioKnobs {
    fn default() -> Self {
        ScenarioKnobs {
            rate: 20.0,
            requests: 2000,
            fleet: 4,
            seed: 42,
        }
    }
}

/// Everything needed to run a scenario.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    pub name: String,
    pub catalog: ModelCatalog,
    pub spec: WorkloadSpec,
    pub fleet: Vec<InstanceConfig>,
    /// (time, instance) failure injections.
    pub failures: Vec<(f64, InstanceId)>,
    /// Runtime autoscaling bounds (the `autoscale` scenario); `fleet`
    /// is the trough-sized starting fleet.
    pub autoscale: Option<AutoscaleConfig>,
    /// Enable submit-time admission control for the run.
    pub admission: bool,
}

impl ScenarioRun {
    /// The simulation config this run prescribes: fleet, catalog,
    /// failure injections, and capacity settings (autoscale bounds +
    /// admission control). Callers layer run-specific knobs on top
    /// (seed, horizon, `--full-solve`, `--threads`). Keeping the
    /// assembly here — and only here — is what guarantees `qlm sim`,
    /// `qlm compare`, and the golden-equivalence suite all run a
    /// scenario under the identical configuration.
    pub fn sim_config(&self, policy: Policy) -> SimConfig {
        let mut cfg = SimConfig::new(self.fleet.clone(), self.catalog.clone(), policy);
        cfg.failures = self.failures.clone();
        cfg.autoscale = self.autoscale;
        if self.admission {
            cfg.admission = AdmissionConfig::enabled();
        }
        cfg
    }
}

impl Scenario {
    pub const ALL: &'static [Scenario] = &[
        Scenario::Burst,
        Scenario::Diurnal,
        Scenario::MixedSlo,
        Scenario::MultiModel,
        Scenario::Failover,
        Scenario::Scale,
        Scenario::Autoscale,
        Scenario::Mega,
        Scenario::Megascale,
        Scenario::Gigascale,
    ];

    pub fn from_name(name: &str) -> Option<Scenario> {
        Some(match name {
            "burst" => Scenario::Burst,
            "diurnal" => Scenario::Diurnal,
            "mixed-slo" => Scenario::MixedSlo,
            "multi-model" => Scenario::MultiModel,
            "failover" => Scenario::Failover,
            "scale" => Scenario::Scale,
            "autoscale" => Scenario::Autoscale,
            "mega" => Scenario::Mega,
            "megascale" => Scenario::Megascale,
            "gigascale" => Scenario::Gigascale,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Burst => "burst",
            Scenario::Diurnal => "diurnal",
            Scenario::MixedSlo => "mixed-slo",
            Scenario::MultiModel => "multi-model",
            Scenario::Failover => "failover",
            Scenario::Scale => "scale",
            Scenario::Autoscale => "autoscale",
            Scenario::Mega => "mega",
            Scenario::Megascale => "megascale",
            Scenario::Gigascale => "gigascale",
        }
    }

    /// One-line description for `qlm sim --list` and the README.
    pub fn description(&self) -> &'static str {
        match self {
            Scenario::Burst => {
                "interactive bursts (MMPP, 6x burst rate) over a steady batch floor"
            }
            Scenario::Diurnal => {
                "sinusoidal day/night interactive rate over a batch floor"
            }
            Scenario::MixedSlo => {
                "the paper's W_A: interactive + batch-1 + batch-2 on one model"
            }
            Scenario::MultiModel => {
                "the paper's W_B: four fine-tuned variants multiplexed by swapping"
            }
            Scenario::Failover => {
                "mixed traffic with one instance killed mid-run (S4 fault tolerance)"
            }
            Scenario::Scale => {
                "100k+ requests, mixed SLO classes, multi-model (Fig. 20 scale)"
            }
            Scenario::Autoscale => {
                "diurnal 4x swing, multi-model, trough fleet + runtime autoscaler"
            }
            Scenario::Mega => {
                "W_A with heavy mega-prompt batch traffic (chunked-prefill stress)"
            }
            Scenario::Megascale => {
                "the scale shape at 1M+ requests (timer-wheel/arena hot-path gate)"
            }
            Scenario::Gigascale => {
                "the scale shape at 10M+ requests (streamed arrivals + sharded queue gate)"
            }
        }
    }

    /// Default headline rate (req/s) that keeps the default fleet at
    /// moderate utilization — pressured but not unserviceable.
    pub fn default_rate(&self) -> f64 {
        match self {
            Scenario::MultiModel => 8.0,
            // Mega prompts carry several thousand prefill tokens each;
            // a lower headline rate keeps the default fleet pressured
            // rather than hopeless.
            Scenario::Mega => 10.0,
            // 1.7 × 100 req/s × 7200 s ≈ 1.22M requests: past the
            // million-request floor with the arrival span still ending
            // at ~85% of the default horizon so the tail drains.
            Scenario::Megascale => 100.0,
            // 1.7 × 850 req/s × 7200 s ≈ 10.4M requests: past the
            // ten-million floor, arrivals still ending at ~85% of the
            // default horizon so the tail drains.
            Scenario::Gigascale => 850.0,
            _ => 12.0,
        }
    }

    /// Default fleet size for the scenario's model mix.
    pub fn default_fleet(&self) -> u32 {
        match self {
            // Vicuna-13B (mixed-slo) and the W_B variant set are far
            // heavier per token than Mistral-7B; give them more devices.
            Scenario::MixedSlo
            | Scenario::MultiModel
            | Scenario::Scale
            | Scenario::Mega
            | Scenario::Megascale
            | Scenario::Gigascale => 8,
            // The autoscale fleet knob is the *trough* size; the
            // autoscaler may grow it 4× (matching the arrival swing).
            Scenario::Autoscale => 4,
            _ => 4,
        }
    }

    /// Request count whose arrival span fills `horizon_s` at `rate`
    /// (per-scenario stream structure), clamped to a sane range.
    pub fn requests_for(&self, rate: f64, horizon_s: f64) -> usize {
        let per_second = match self {
            // W_A: interactive at R spans (n/2)/R; batch streams match.
            Scenario::MixedSlo | Scenario::Failover | Scenario::Mega => 2.0 * rate,
            // Two-stream shape: interactive 2n/3 at R.
            Scenario::Burst | Scenario::Diurnal => 1.5 * rate,
            // W_B: the half-rate Batch-2 stream is the long pole.
            Scenario::MultiModel => rate,
            // Arrivals stop at ~85% of the horizon so the tail drains
            // and the run *completes* inside it (Fig. 20 regime).
            Scenario::Scale
            | Scenario::Autoscale
            | Scenario::Megascale
            | Scenario::Gigascale => 1.7 * rate,
        };
        let (lo, hi) = match self {
            // The floor *is* the point: `megascale` must queue a
            // million-plus requests whatever the knobs — the hot-path
            // gate for the timer wheel, arena storage, and stealing
            // lanes runs here.
            Scenario::Megascale => (1_000_000, 4_000_000),
            // And `gigascale` ten million: the streamed-arrival +
            // sharded-broker gate. Only the stream path should build
            // it — a materialized trace this size is the bug the
            // scenario exists to catch.
            Scenario::Gigascale => (10_000_000, 40_000_000),
            Scenario::Scale | Scenario::Autoscale => (100_000, 400_000),
            _ => (200, 400_000),
        };
        ((per_second * horizon_s) as usize).clamp(lo, hi)
    }

    /// Expand the scenario into a concrete run description.
    pub fn build(&self, k: &ScenarioKnobs) -> ScenarioRun {
        let base = ScenarioRun {
            name: self.name().to_string(),
            catalog: ModelCatalog::paper(),
            spec: WorkloadSpec::w_a(ModelId(0), k.rate, k.requests),
            fleet: fleet_a100(k.fleet),
            failures: Vec::new(),
            autoscale: None,
            admission: false,
        };
        match self {
            Scenario::MixedSlo => ScenarioRun {
                // W_A on Vicuna-13B: the heaviest per-token model that
                // still fits a single A100 — the §8.1 setting.
                spec: WorkloadSpec::w_a(ModelId(1), k.rate, k.requests),
                ..base
            },
            Scenario::Burst => ScenarioRun {
                spec: two_stream_spec(
                    "burst",
                    ArrivalProcess::Bursty {
                        rate: k.rate,
                        burstiness: 6.0,
                        phase_len_s: 5.0,
                    },
                    k,
                ),
                ..base
            },
            Scenario::Diurnal => ScenarioRun {
                spec: two_stream_spec(
                    "diurnal",
                    ArrivalProcess::Diurnal {
                        base_rate: k.rate * 0.2,
                        peak_rate: k.rate * 2.0,
                        period_s: 1800.0,
                    },
                    k,
                ),
                ..base
            },
            Scenario::MultiModel => ScenarioRun {
                catalog: ModelCatalog::paper_multi_model(),
                spec: WorkloadSpec::w_b(
                    vec![ModelId(3), ModelId(4)],
                    vec![ModelId(5), ModelId(6)],
                    k.rate,
                    k.requests,
                ),
                // A10/A100 mix exercises hardware heterogeneity too.
                fleet: fleet_mixed(k.fleet.max(2), 0.25),
                ..base
            },
            Scenario::Scale => ScenarioRun {
                catalog: ModelCatalog::paper_multi_model(),
                spec: scale_spec(k),
                ..base
            },
            Scenario::Autoscale => {
                let trough = k.fleet.max(2);
                ScenarioRun {
                    catalog: ModelCatalog::paper_multi_model(),
                    spec: autoscale_spec(k),
                    fleet: fleet_of(GpuKind::A100, trough),
                    autoscale: Some(AutoscaleConfig::bounded(trough, trough * 4, GpuKind::A100)),
                    admission: true,
                    ..base
                }
            }
            Scenario::Mega => ScenarioRun {
                spec: mega_spec(k),
                ..base
            },
            Scenario::Megascale => {
                // Same stream structure as `scale` — the point is the
                // request count, not a new traffic shape.
                let mut spec = scale_spec(k);
                spec.name = format!("megascale(rate={})", k.rate);
                ScenarioRun {
                    catalog: ModelCatalog::paper_multi_model(),
                    spec,
                    ..base
                }
            }
            Scenario::Gigascale => {
                // The scale shape again, an order of magnitude past
                // megascale. The spec is cheap to build (three stream
                // descriptors); expanding it is what must go through
                // the streamed path.
                let mut spec = scale_spec(k);
                spec.name = format!("gigascale(rate={})", k.rate);
                ScenarioRun {
                    catalog: ModelCatalog::paper_multi_model(),
                    spec,
                    ..base
                }
            }
            Scenario::Failover => {
                let fleet = fleet_a100(k.fleet.max(2));
                // Kill the last instance a tenth into the nominal run:
                // late enough to have real in-flight state, early enough
                // that the survivors must absorb most of the trace.
                let victim = InstanceId(fleet.len() as u32 - 1);
                ScenarioRun {
                    spec: WorkloadSpec::w_a(ModelId(0), k.rate, k.requests),
                    fleet,
                    failures: vec![(60.0, victim)],
                    ..base
                }
            }
        }
    }
}

/// The `scale` workload: interactive traffic on the base Mistral-7B
/// plus two batch classes on fine-tuned variants, sized so the queue
/// holds 100K+ requests at the default knobs — the live-run analogue of
/// the paper's Fig. 20 overhead study. Multiple models and SLO classes
/// keep the group table heterogeneous (many clusters per queue), which
/// is the hard case for the incremental scheduler.
fn scale_spec(k: &ScenarioKnobs) -> WorkloadSpec {
    let n_i = k.requests / 2;
    let n_b1 = k.requests / 4;
    let n_b2 = k.requests - n_i - n_b1;
    WorkloadSpec {
        name: format!("scale(rate={})", k.rate),
        streams: vec![
            RequestClassSpec {
                class: SloClass::Interactive,
                models: vec![ModelId(0)],
                arrivals: ArrivalProcess::Poisson { rate: k.rate },
                count: n_i,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch1,
                models: vec![ModelId(3)],
                arrivals: ArrivalProcess::Poisson { rate: k.rate * 0.5 },
                count: n_b1,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch2,
                models: vec![ModelId(5)],
                arrivals: ArrivalProcess::Poisson { rate: k.rate * 0.5 },
                count: n_b2,
                mega_fraction: 0.0,
            },
        ],
        sampler: ShareGptSampler::default(),
    }
}

/// The `autoscale` workload: interactive traffic riding a diurnal wave
/// with a 4× peak-to-trough swing (base ½×rate, peak 2×rate) on the
/// base Mistral-7B, plus two batch classes on fine-tuned variants — the
/// regime where a fixed fleet is either over-provisioned at the trough
/// or under-provisioned at the peak (Fig. 1), i.e. exactly what the
/// runtime autoscaler exists for. Batch streams run at 0.7×rate so
/// arrivals stop at ~70% of the horizon and the tail (and any final
/// drain) completes inside it.
fn autoscale_spec(k: &ScenarioKnobs) -> WorkloadSpec {
    let n_i = k.requests / 2;
    let n_b1 = k.requests / 4;
    let n_b2 = k.requests - n_i - n_b1;
    WorkloadSpec {
        name: format!("autoscale(rate={})", k.rate),
        streams: vec![
            RequestClassSpec {
                class: SloClass::Interactive,
                models: vec![ModelId(0)],
                arrivals: ArrivalProcess::Diurnal {
                    base_rate: k.rate * 0.5,
                    peak_rate: k.rate * 2.0,
                    period_s: 1800.0,
                },
                count: n_i,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch1,
                models: vec![ModelId(3)],
                arrivals: ArrivalProcess::Poisson { rate: k.rate * 0.7 },
                count: n_b1,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch2,
                models: vec![ModelId(5)],
                arrivals: ArrivalProcess::Poisson { rate: k.rate * 0.7 },
                count: n_b2,
                mega_fraction: 0.0,
            },
        ],
        sampler: ShareGptSampler::default(),
    }
}

/// The `mega` workload: W_A's 50/25/25 class split on Vicuna-13B, but
/// with a third of each batch stream drawn from the mega-prompt sampler
/// (3K–4K total tokens, W_C's long-prompt regime). Interactive requests
/// stay short — the stress is entirely in how long a mega prefill holds
/// the iteration hostage, which is what chunked prefill dismantles.
fn mega_spec(k: &ScenarioKnobs) -> WorkloadSpec {
    let mut w = WorkloadSpec::w_a(ModelId(1), k.rate, k.requests);
    w.name = format!("mega(rate={})", k.rate);
    for s in &mut w.streams {
        if s.class != SloClass::Interactive {
            s.mega_fraction = 0.35;
        }
    }
    w
}

/// Interactive stream under `arrivals` + a relaxed batch floor at half
/// the headline rate — the shape shared by the burst/diurnal scenarios.
fn two_stream_spec(name: &str, arrivals: ArrivalProcess, k: &ScenarioKnobs) -> WorkloadSpec {
    let n_i = k.requests * 2 / 3;
    WorkloadSpec {
        name: format!("{name}(rate={})", k.rate),
        streams: vec![
            RequestClassSpec {
                class: SloClass::Interactive,
                models: vec![ModelId(0)],
                arrivals,
                count: n_i,
                mega_fraction: 0.0,
            },
            RequestClassSpec {
                class: SloClass::Batch1,
                models: vec![ModelId(0)],
                arrivals: ArrivalProcess::Poisson { rate: k.rate * 0.5 },
                count: k.requests - n_i,
                mega_fraction: 0.0,
            },
        ],
        sampler: ShareGptSampler::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Trace;

    #[test]
    fn names_round_trip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::from_name(s.name()), Some(*s));
            assert!(!s.description().is_empty());
        }
        assert_eq!(Scenario::from_name("nope"), None);
    }

    #[test]
    fn every_scenario_generates_a_trace() {
        let k = ScenarioKnobs {
            requests: 300,
            ..Default::default()
        };
        for s in Scenario::ALL {
            let run = s.build(&k);
            let trace = Trace::generate(&run.spec, k.seed);
            assert_eq!(trace.len(), 300, "{}", s.name());
            assert!(!run.fleet.is_empty(), "{}", s.name());
            for m in trace.models() {
                assert!(
                    (m.0 as usize) < run.catalog.models.len(),
                    "{}: model {m:?} outside catalog",
                    s.name()
                );
            }
        }
    }

    #[test]
    fn failover_kills_a_real_instance() {
        let run = Scenario::Failover.build(&ScenarioKnobs::default());
        assert_eq!(run.failures.len(), 1);
        let (t, inst) = run.failures[0];
        assert!(t > 0.0);
        assert!(run.fleet.iter().any(|c| c.id == inst));
    }

    #[test]
    fn multi_model_uses_variant_catalog() {
        let run = Scenario::MultiModel.build(&ScenarioKnobs::default());
        assert!(run.catalog.models.len() >= 7);
    }

    #[test]
    fn scale_scenario_sizes_to_100k_requests() {
        let s = Scenario::Scale;
        let n = s.requests_for(s.default_rate(), 7200.0);
        assert!(n >= 100_000, "{n}");
        // Arrivals stop well before the horizon so the tail can drain.
        let rate = s.default_rate();
        let span = (n as f64 / 2.0) / rate;
        assert!(span <= 0.9 * 7200.0, "arrival span {span}");
    }

    #[test]
    fn autoscale_scenario_shape() {
        let k = ScenarioKnobs::default();
        let run = Scenario::Autoscale.build(&k);
        let auto = run.autoscale.expect("autoscaler must be configured");
        assert_eq!(auto.min_instances as usize, run.fleet.len());
        assert_eq!(auto.max_instances, auto.min_instances * 4);
        assert!(run.admission, "admission control rides along");
        // 4× peak-to-trough swing on the interactive stream.
        let inter = &run.spec.streams[0];
        match inter.arrivals {
            ArrivalProcess::Diurnal { base_rate, peak_rate, .. } => {
                assert!((peak_rate / base_rate - 4.0).abs() < 1e-9);
            }
            ref other => panic!("expected diurnal arrivals, got {other:?}"),
        }
        // The prescribed sim config carries the capacity settings.
        let cfg = run.sim_config(Policy::qlm());
        assert!(cfg.admission.enabled, "admission must reach the config");
        assert!(cfg.autoscale.is_some(), "autoscaler must reach the config");
        assert_eq!(cfg.fleet.len(), run.fleet.len());
        // Mixed SLO classes over multiple models.
        let classes: std::collections::BTreeSet<_> =
            run.spec.streams.iter().map(|s| s.class).collect();
        assert_eq!(classes.len(), 3);
        let models: std::collections::BTreeSet<_> = run
            .spec
            .streams
            .iter()
            .flat_map(|s| s.models.iter().copied())
            .collect();
        assert!(models.len() >= 3);
        // CLI-default sizing reaches the 100k-request floor with the
        // arrival span ending well inside the horizon.
        let rate = Scenario::Autoscale.default_rate();
        let n = Scenario::Autoscale.requests_for(rate, 7200.0);
        assert!(n >= 100_000, "{n}");
        let batch_span = (n as f64 / 4.0) / (rate * 0.7);
        assert!(batch_span <= 0.85 * 7200.0, "batch span {batch_span}");
        let inter_span = (n as f64 / 2.0) / (rate * 1.25); // diurnal mean
        assert!(inter_span <= 0.85 * 7200.0, "interactive span {inter_span}");
    }

    #[test]
    fn megascale_scenario_sizes_to_a_million_requests() {
        let s = Scenario::Megascale;
        let n = s.requests_for(s.default_rate(), 7200.0);
        assert!(n >= 1_000_000, "{n}");
        // Even hostile knobs can't shrink it below the floor.
        assert!(s.requests_for(0.001, 1.0) >= 1_000_000);
        // Arrivals still stop inside the horizon at the default rate.
        let span = (n as f64 / 2.0) / s.default_rate();
        assert!(span <= 0.9 * 7200.0, "arrival span {span}");
        // Same mixed-SLO multi-model shape as `scale`.
        let run = s.build(&ScenarioKnobs::default());
        assert!(run.spec.name.starts_with("megascale"));
        let classes: std::collections::BTreeSet<_> =
            run.spec.streams.iter().map(|s| s.class).collect();
        assert!(classes.len() >= 3, "mixed SLO classes required");
        assert!(run.catalog.models.len() >= 7);
    }

    #[test]
    fn gigascale_scenario_sizes_to_ten_million_requests() {
        let s = Scenario::Gigascale;
        let n = s.requests_for(s.default_rate(), 7200.0);
        assert!(n >= 10_000_000, "{n}");
        // Even hostile knobs can't shrink it below the floor.
        assert!(s.requests_for(0.001, 1.0) >= 10_000_000);
        // Arrivals still stop inside the horizon at the default rate.
        let span = (n as f64 / 2.0) / s.default_rate();
        assert!(span <= 0.9 * 7200.0, "arrival span {span}");
        // Same mixed-SLO multi-model shape as `scale` — but note: the
        // spec here is only descriptors; expanding 10M requests must go
        // through `ArrivalStream`, never `Trace::generate`.
        let run = s.build(&ScenarioKnobs::default());
        assert!(run.spec.name.starts_with("gigascale"));
        let classes: std::collections::BTreeSet<_> =
            run.spec.streams.iter().map(|s| s.class).collect();
        assert!(classes.len() >= 3, "mixed SLO classes required");
        assert!(run.catalog.models.len() >= 7);
    }

    #[test]
    fn mega_scenario_loads_batch_streams_with_long_prompts() {
        let run = Scenario::Mega.build(&ScenarioKnobs::default());
        assert_eq!(run.spec.streams.len(), 3);
        for s in &run.spec.streams {
            assert_eq!(s.models, vec![ModelId(1)], "single shared model");
            if s.class == SloClass::Interactive {
                assert_eq!(s.mega_fraction, 0.0, "interactive stays short");
            } else {
                assert!(s.mega_fraction > 0.0, "batch carries the mega load");
            }
        }
        let trace = Trace::generate(&run.spec, 1);
        let megas = trace.requests.iter().filter(|r| r.mega).count();
        assert!(megas > 0, "trace must contain mega prompts");
        assert!(
            trace
                .requests
                .iter()
                .filter(|r| r.mega)
                .all(|r| r.class != SloClass::Interactive),
            "mega prompts ride the batch classes only"
        );
    }

    #[test]
    fn scale_scenario_is_mixed_slo_and_multi_model() {
        let run = Scenario::Scale.build(&ScenarioKnobs::default());
        let classes: std::collections::BTreeSet<_> =
            run.spec.streams.iter().map(|s| s.class).collect();
        assert!(classes.len() >= 3, "mixed SLO classes required");
        let models: std::collections::BTreeSet<_> = run
            .spec
            .streams
            .iter()
            .flat_map(|s| s.models.iter().copied())
            .collect();
        assert!(models.len() >= 3, "multi-model required");
        assert!(run.catalog.models.len() >= 7);
        // Every model in the mix fits the A100 fleet.
        for m in &models {
            assert!(
                crate::backend::PerfModel::try_profile(
                    run.catalog.get(*m),
                    crate::backend::GpuKind::A100,
                    161.0
                )
                .is_some(),
                "model {m:?} must be servable"
            );
        }
    }
}
