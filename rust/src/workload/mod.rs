//! Workload generation: ShareGPT-fitted token distributions, Poisson /
//! bursty arrival processes, SLO classes, and the paper's three evaluation
//! workloads W_A, W_B, W_C (§8).

pub mod sharegpt;
pub mod arrivals;
pub mod spec;
pub mod stream;
pub mod trace;
pub mod scenarios;

pub use sharegpt::ShareGptSampler;
pub use arrivals::{ArrivalProcess, Arrivals};
pub use scenarios::{Scenario, ScenarioKnobs, ScenarioRun};
pub use spec::{RequestClassSpec, SloClass, SloTarget, WorkloadSpec};
pub use stream::ArrivalStream;
pub use trace::{Trace, TraceRequest};
