"""Layer-1 correctness: Pallas decode-attention kernel vs pure-jnp oracle.

The CORE correctness signal for the compute layer — the same kernel code
lowers into the HLO the rust runtime executes. Hypothesis sweeps shapes,
lengths, chunk sizes, and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import (
    decode_attention,
    mxu_flops_per_instance,
    vmem_bytes,
)
from compile.kernels.ref import decode_attention_ref


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


def run_case(b, s, h, d, lengths, chunk, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, d), dtype)
    k = _rand(rng, (b, s, h, d), dtype)
    v = _rand(rng, (b, s, h, d), dtype)
    lengths = jnp.asarray(lengths, jnp.int32)
    out = decode_attention(q, k, v, lengths, chunk=chunk)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=2e-3)


class TestDecodeAttentionBasics:
    def test_full_length(self):
        run_case(2, 128, 4, 16, [128, 128], 64)

    def test_partial_lengths(self):
        run_case(3, 128, 4, 16, [1, 64, 97], 64)

    def test_single_token_cache(self):
        run_case(2, 64, 2, 8, [1, 1], 32)

    def test_unaligned_seq_padding(self):
        # S not a multiple of chunk: kernel pads internally.
        run_case(2, 100, 4, 16, [100, 37], 64)

    def test_chunk_larger_than_seq(self):
        run_case(1, 32, 2, 16, [20], 128)

    def test_single_head(self):
        run_case(2, 64, 1, 32, [64, 10], 32)

    def test_batch_one(self):
        run_case(1, 256, 4, 16, [173], 128)

    def test_bf16_inputs(self):
        run_case(2, 64, 2, 16, [64, 30], 32, dtype=jnp.bfloat16)

    def test_deterministic(self):
        rng = np.random.default_rng(7)
        q = _rand(rng, (2, 4, 16))
        k = _rand(rng, (2, 64, 4, 16))
        v = _rand(rng, (2, 64, 4, 16))
        lengths = jnp.asarray([64, 9], jnp.int32)
        a = decode_attention(q, k, v, lengths, chunk=32)
        b = decode_attention(q, k, v, lengths, chunk=32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_chunk_invariance(self):
        # The online softmax must make the result independent of chunking.
        rng = np.random.default_rng(8)
        q = _rand(rng, (2, 4, 16))
        k = _rand(rng, (2, 128, 4, 16))
        v = _rand(rng, (2, 128, 4, 16))
        lengths = jnp.asarray([128, 55], jnp.int32)
        outs = [
            np.asarray(decode_attention(q, k, v, lengths, chunk=c))
            for c in (16, 32, 64, 128)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)

    def test_extreme_scores_stable(self):
        # Large-magnitude logits: online softmax must not overflow.
        rng = np.random.default_rng(9)
        q = _rand(rng, (1, 2, 16)) * 100.0
        k = _rand(rng, (1, 64, 2, 16)) * 100.0
        v = _rand(rng, (1, 64, 2, 16))
        lengths = jnp.asarray([64], jnp.int32)
        out = decode_attention(q, k, v, lengths, chunk=32)
        assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    s=st.integers(8, 160),
    chunk=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_decode_attention_hypothesis(b, h, d, s, chunk, seed, data):
    lengths = data.draw(
        st.lists(st.integers(1, s), min_size=b, max_size=b), label="lengths"
    )
    run_case(b, s, h, d, lengths, chunk, seed=seed)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(8, 96),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_decode_attention_hypothesis_bf16(b, s, seed, data):
    lengths = data.draw(
        st.lists(st.integers(1, s), min_size=b, max_size=b), label="lengths"
    )
    run_case(b, s, 2, 16, lengths, 32, dtype=jnp.bfloat16, seed=seed)


class TestPerfEstimators:
    def test_vmem_within_budget(self):
        # Production shape: 16 heads x 128 dim, 512-token chunks.
        assert vmem_bytes(16, 128, 512) < 16 * 1024 * 1024

    def test_flops_scale_with_chunk(self):
        assert mxu_flops_per_instance(4, 16, 128) == 2 * mxu_flops_per_instance(4, 16, 64)
