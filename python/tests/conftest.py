"""Collection guards for optional heavy dependencies.

The Pallas/JAX layer is exercised only where JAX is installed (the CI
python job, developer machines with `jax[cpu]`). Everywhere else the
suite must still be invocable — `python -m pytest python/tests -q`
reports the modules as skipped rather than erroring at import time.
"""

import importlib.util

collect_ignore = []

if importlib.util.find_spec("jax") is None:
    # Both layers import jax at module scope.
    collect_ignore += ["test_kernel.py", "test_model.py"]
elif importlib.util.find_spec("hypothesis") is None:
    # The kernel sweep additionally property-tests with hypothesis.
    collect_ignore += ["test_kernel.py"]
