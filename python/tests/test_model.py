"""Layer-2 correctness: prefill/decode shapes, kernel-vs-oracle decode
parity, autoregressive consistency, and AOT lowering round-trips."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    bound_model,
    decode_step,
    decode_step_ref,
    init_params,
    prefill,
)


@pytest.fixture(scope="module")
def model():
    return bound_model()


def random_prompt(cfg, b, lengths, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab - 1, size=(b, cfg.max_seq)), jnp.int32
    )
    return tokens, jnp.asarray(lengths, jnp.int32)


class TestPrefill:
    def test_shapes(self, model):
        cfg, params = model
        tokens, lengths = random_prompt(cfg, 2, [10, 50])
        logits, k, v = prefill(params, cfg, tokens, lengths)
        assert logits.shape == (2, cfg.vocab)
        assert k.shape == (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        assert v.shape == k.shape

    def test_logits_depend_only_on_valid_prefix(self, model):
        cfg, params = model
        tokens, lengths = random_prompt(cfg, 1, [10], seed=1)
        logits_a, _, _ = prefill(params, cfg, tokens, lengths)
        # Scramble the padding region; logits must not change.
        scrambled = tokens.at[:, 10:].set((tokens[:, 10:] + 17) % cfg.vocab)
        logits_b, _, _ = prefill(params, cfg, scrambled, lengths)
        np.testing.assert_allclose(logits_a, logits_b, atol=1e-5)

    def test_batch_consistency(self, model):
        # Same prompt alone vs batched with another: same logits.
        cfg, params = model
        tokens, _ = random_prompt(cfg, 2, [20, 40], seed=2)
        lengths = jnp.asarray([20, 40], jnp.int32)
        logits_batch, _, _ = prefill(params, cfg, tokens, lengths)
        logits_solo, _, _ = prefill(
            params, cfg, tokens[:1], jnp.asarray([20], jnp.int32)
        )
        np.testing.assert_allclose(logits_batch[0], logits_solo[0], atol=1e-4, rtol=1e-4)


class TestDecode:
    def test_kernel_matches_oracle(self, model):
        cfg, params = model
        tokens, lengths = random_prompt(cfg, 3, [5, 30, 100], seed=3)
        _, k, v = prefill(params, cfg, tokens, lengths)
        step_tokens = jnp.asarray([1, 2, 3], jnp.int32)
        l1, k1, v1 = decode_step(params, cfg, step_tokens, k, v, lengths)
        l2, k2, v2 = decode_step_ref(params, cfg, step_tokens, k, v, lengths)
        np.testing.assert_allclose(l1, l2, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(k1, k2, atol=1e-5)
        np.testing.assert_allclose(v1, v2, atol=1e-5)

    def test_decode_matches_prefill_extension(self, model):
        # Greedy-decoding one token then prefilling prompt+token must give
        # consistent next-step logits (autoregressive consistency).
        cfg, params = model
        n = 12
        tokens, lengths = random_prompt(cfg, 1, [n], seed=4)
        logits_p, k, v = prefill(params, cfg, tokens, lengths)
        next_tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
        # Path A: decode_step after prefill.
        logits_d, _, _ = decode_step(params, cfg, next_tok, k, v, lengths)
        # Path B: prefill over the extended prompt.
        ext = tokens.at[0, n].set(next_tok[0])
        logits_e, _, _ = prefill(params, cfg, ext, jnp.asarray([n + 1], jnp.int32))
        np.testing.assert_allclose(logits_d, logits_e, atol=2e-3, rtol=2e-3)

    def test_multi_step_generation_finite(self, model):
        cfg, params = model
        tokens, lengths = random_prompt(cfg, 2, [8, 16], seed=5)
        logits, k, v = prefill(params, cfg, tokens, lengths)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        ln = lengths
        for _ in range(5):
            logits, k, v = decode_step(params, cfg, cur, k, v, ln)
            assert np.isfinite(np.asarray(logits)).all()
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            ln = ln + 1

    def test_batch_entry_isolation(self, model):
        # Changing one sequence must not affect another's logits.
        cfg, params = model
        tokens, lengths = random_prompt(cfg, 2, [20, 20], seed=6)
        _, k, v = prefill(params, cfg, tokens, lengths)
        t_a = jnp.asarray([1, 2], jnp.int32)
        t_b = jnp.asarray([1, 200], jnp.int32)  # second seq token differs
        la, _, _ = decode_step(params, cfg, t_a, k, v, lengths)
        lb, _, _ = decode_step(params, cfg, t_b, k, v, lengths)
        np.testing.assert_allclose(la[0], lb[0], atol=1e-5)
        assert np.abs(np.asarray(la[1] - lb[1])).max() > 1e-4


class TestDeterminism:
    def test_weights_deterministic_by_seed(self):
        a = init_params(ModelConfig())
        b = init_params(ModelConfig())
        np.testing.assert_array_equal(np.asarray(a["embed"]), np.asarray(b["embed"]))
        c = init_params(ModelConfig(seed=1))
        assert np.abs(np.asarray(a["embed"] - c["embed"])).max() > 0

    def test_param_count_formula(self):
        cfg = ModelConfig()
        params = init_params(cfg)
        total = 0
        def count(t):
            nonlocal total
            total += int(np.prod(t.shape))
        jax.tree_util.tree_map(count, params)
        assert total == cfg.param_count


class TestArtifacts:
    """Validate the AOT manifest when artifacts have been built."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "manifest.json")),
        reason="run `make artifacts` first",
    )
    def test_manifest_consistent_with_model(self):
        cfg, _ = bound_model()
        with open(os.path.join(self.ART, "manifest.json")) as f:
            m = json.load(f)
        assert m["vocab"] == cfg.vocab
        assert m["n_layers"] == cfg.n_layers
        assert m["max_seq"] == cfg.max_seq
        for b in m["buckets"]:
            for kind in ("prefill", "decode"):
                p = os.path.join(self.ART, b[kind])
                assert os.path.exists(p), p
                with open(p) as f:
                    head = f.read(65536)
                assert "ENTRY" in head
                # Weights must not be elided from the text.
                assert "{...}" not in head

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "manifest.json")),
        reason="run `make artifacts` first",
    )
    def test_hlo_entry_signatures(self):
        with open(os.path.join(self.ART, "manifest.json")) as f:
            m = json.load(f)
        b1 = next(b for b in m["buckets"] if b["batch"] == 1)
        text = open(os.path.join(self.ART, b1["decode"])).read()
        # decode entry takes 4 runtime parameters (tokens, k, v, lengths);
        # ENTRY is the final computation in the text dump.
        entry = text[text.index("ENTRY") :]
        n_params = entry.count("parameter(")
        assert n_params == 4, f"found {n_params} entry parameters"
