"""AOT export: lower the Layer-2 model (with its Layer-1 Pallas kernels)
to HLO *text* for the rust PJRT runtime.

HLO text, not serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts, per batch bucket B in {1, 4, 8}:
  artifacts/prefill_b{B}.hlo.txt   (tokens[B,S], lengths[B]) -> (logits, k, v)
  artifacts/decode_b{B}.hlo.txt    (tokens[B], k, v, lengths[B]) -> (logits, k, v)
  artifacts/manifest.json          shapes + model config for the rust loader
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import ModelConfig, bound_model, decode_step, prefill

BATCH_BUCKETS = (1, 4, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked weights MUST survive the text
    # round-trip (default printing elides them as `{...}`).
    return comp.as_hlo_text(True)


def lower_prefill(cfg: ModelConfig, params, b: int) -> str:
    s = cfg.max_seq

    def fn(tokens, lengths):
        return prefill(params, cfg, tokens, lengths)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_decode(cfg: ModelConfig, params, b: int) -> str:
    s = cfg.max_seq
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, b, s, cfg.n_heads, cfg.head_dim), jnp.float32
    )

    def fn(tokens, k, v, lengths):
        return decode_step(params, cfg, tokens, k, v, lengths)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((b,), jnp.int32),
        cache,
        cache,
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="stamp file; artifacts land in its directory")
    ap.add_argument("--buckets", type=int, nargs="*", default=list(BATCH_BUCKETS))
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)
    cfg, params = bound_model()

    manifest = {
        "model": "tiny-qlm",
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "max_seq": cfg.max_seq,
        "param_count": cfg.param_count,
        "seed": cfg.seed,
        "buckets": [],
    }
    for b in args.buckets:
        pre = lower_prefill(cfg, params, b)
        dec = lower_decode(cfg, params, b)
        pre_path = os.path.join(out_dir, f"prefill_b{b}.hlo.txt")
        dec_path = os.path.join(out_dir, f"decode_b{b}.hlo.txt")
        with open(pre_path, "w") as f:
            f.write(pre)
        with open(dec_path, "w") as f:
            f.write(dec)
        manifest["buckets"].append({
            "batch": b,
            "prefill": os.path.basename(pre_path),
            "decode": os.path.basename(dec_path),
        })
        print(f"bucket B={b}: prefill {len(pre)//1024} KiB, decode {len(dec)//1024} KiB")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Plain-text twin for the dependency-free rust loader.
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for key in ("vocab", "d_model", "n_layers", "n_heads", "head_dim",
                    "max_seq", "param_count", "seed"):
            f.write(f"{key} {manifest[key]}\n")
        for b in manifest["buckets"]:
            f.write(f"bucket {b['batch']} {b['prefill']} {b['decode']}\n")
    # Stamp file for make's dependency tracking.
    with open(os.path.abspath(args.out), "w") as f:
        f.write("artifacts built\n")
    print(f"wrote manifest + stamp to {out_dir}")


if __name__ == "__main__":
    main()
