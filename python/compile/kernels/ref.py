"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness signal).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops only. pytest (and hypothesis sweeps) assert
allclose between kernel and oracle across shapes/dtypes.
"""

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-token decode attention over a padded KV cache.

    Args:
      q:        [B, H, D]      query for the current decode step.
      k_cache:  [B, S, H, D]   padded key cache.
      v_cache:  [B, S, H, D]   padded value cache.
      lengths:  [B] int32      valid tokens per sequence (<= S).

    Returns:
      [B, H, D] attention output, f32.
    """
    q = q.astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # scores: [B, H, S]
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    s = k.shape[1]
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = jnp.where(mask, probs, 0.0)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", probs, v)


def matmul_ref(a, b):
    """Tiled-matmul oracle: plain f32 matmul."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def rmsnorm_ref(x, w, eps=1e-6):
    """RMSNorm oracle."""
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w.astype(jnp.float32)
