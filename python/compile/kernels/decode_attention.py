"""Layer-1 Pallas kernel: decode attention over the KV cache.

The paper's serving hot spot is vLLM's PagedAttention decode step (CUDA:
one warp group per head, shared-memory tiles over KV pages). The TPU
rethink (DESIGN.md SS Hardware-Adaptation):

  * the KV cache streams HBM->VMEM in BlockSpec tiles over a (batch,
    kv-chunk) grid -- BlockSpec plays the role threadblock tiling plays
    on GPU;
  * q.k^T and p.v contractions are shaped for the MXU (lane-dim 128
    friendly head_dim, f32 accumulation);
  * an online-softmax (flash-style running max / denominator carried in
    VMEM scratch across the kv-chunk grid dimension) makes one pass over
    the cache suffice, so VMEM residency is O(chunk), not O(S).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; TPU performance is estimated analytically in
EXPERIMENTS.md SSPerf from the VMEM footprint and MXU utilization of
these block shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_CHUNK = 128  # kv positions per VMEM tile; multiple of MXU lanes.


def _decode_attn_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, chunk, kv_chunks):
    """Grid: (batch, kv_chunks). One program instance handles one
    (sequence, kv-chunk) pair for all heads; scratch carries the online
    softmax state across the kv-chunk dimension (innermost grid axis).
    """
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [H, D]
    k = k_ref[0].astype(jnp.float32)            # [chunk, H, D]
    v = v_ref[0].astype(jnp.float32)            # [chunk, H, D]
    length = lengths_ref[0]

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # scores: [H, chunk] -- MXU-shaped contraction over D.
    scores = jnp.einsum("hd,chd->hc", q, k) * scale

    # Mask positions beyond the sequence length.
    pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    valid = pos < length                         # [1, chunk]
    scores = jnp.where(valid, scores, -1e30)

    # Online softmax update.
    m_prev = m_ref[...]                          # [H, 1]
    l_prev = l_ref[...]                          # [H, 1]
    acc_prev = acc_ref[...]                      # [H, D]

    m_cur = jnp.max(scores, axis=-1, keepdims=True)       # [H, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                        # rescale old
    p = jnp.exp(scores - m_new)                            # [H, chunk]
    p = jnp.where(valid, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = alpha * acc_prev + jnp.einsum("hc,chd->hd", p, v)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(c == kv_chunks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def decode_attention(q, k_cache, v_cache, lengths, *, chunk=DEFAULT_CHUNK):
    """Pallas decode attention.

    Args:
      q:        [B, H, D]    current-step queries.
      k_cache:  [B, S, H, D] padded key cache (S % chunk == 0 after pad).
      v_cache:  [B, S, H, D] padded value cache.
      lengths:  [B] int32    valid tokens per sequence.
      chunk:    kv positions per VMEM tile.

    Returns:
      [B, H, D] f32 attention output.
    """
    b, h, d = q.shape
    s = k_cache.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        cfg = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, cfg)
        v_cache = jnp.pad(v_cache, cfg)
        s += pad
    kv_chunks = s // chunk

    kernel = functools.partial(
        _decode_attn_kernel, chunk=chunk, kv_chunks=kv_chunks
    )
    return pl.pallas_call(
        kernel,
        grid=(b, kv_chunks),
        in_specs=[
            pl.BlockSpec((1,), lambda i, c: (i,)),                     # lengths
            pl.BlockSpec((1, h, d), lambda i, c: (i, 0, 0)),           # q
            pl.BlockSpec((1, chunk, h, d), lambda i, c: (i, c, 0, 0)), # k tile
            pl.BlockSpec((1, chunk, h, d), lambda i, c: (i, c, 0, 0)), # v tile
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i, c: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        scratch_shapes=[
            # Online-softmax carry: running max, denominator, accumulator.
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
        interpret=True,
    )(lengths, q, k_cache, v_cache)


def vmem_bytes(h, d, chunk):
    """Estimated VMEM residency of one program instance (f32)."""
    q = h * d * 4
    kv = 2 * chunk * h * d * 4
    scratch = (2 * h + h * d) * 4
    out = h * d * 4
    return q + kv + scratch + out


def mxu_flops_per_instance(h, d, chunk):
    """MAC-FLOPs the MXU executes per (seq, chunk) instance."""
    return 2 * h * d * chunk * 2  # q.k and p.v contractions
