"""Layer-2: a tiny GPT-style decoder in JAX, the real model served
end-to-end by the rust runtime (examples/e2e_serve.rs).

Two entry points are AOT-lowered per batch bucket (aot.py):

  * ``prefill(tokens, lengths)``   -> (logits, k_cache, v_cache)
  * ``decode_step(tokens, k, v, lengths)`` -> (logits, k, v)

The decode step's attention is the Layer-1 Pallas kernel
(kernels/decode_attention.py), so the kernel lowers into the same HLO
module the rust PJRT client executes. Weights are generated
deterministically from a seed and baked into the HLO as constants: the
rust side feeds only tokens/caches.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import rmsnorm_ref as rmsnorm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Matches rust `ModelCatalog::tiny()`: 4 layers x 4 heads x 16 dim."""
    vocab: int = 256          # byte-level tokenizer
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 16
    d_ff: int = 256
    max_seq: int = 256
    seed: int = 20240711

    @property
    def param_count(self):
        l = self.n_layers
        attn = 4 * self.d_model * self.n_heads * self.head_dim
        ff = 2 * self.d_model * self.d_ff
        norms = 2 * self.d_model
        embed = self.vocab * self.d_model
        return l * (attn + ff + norms) + 2 * embed + self.d_model


def init_params(cfg: ModelConfig):
    """Deterministic weights from cfg.seed."""
    key = jax.random.PRNGKey(cfg.seed)
    keys = jax.random.split(key, 2 + cfg.n_layers)
    scale = 0.02

    def dense(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    params = {
        "embed": dense(keys[0], (cfg.vocab, cfg.d_model)),
        "unembed": dense(keys[1], (cfg.d_model, cfg.vocab)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    h = cfg.n_heads * cfg.head_dim
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 6)
        params["layers"].append({
            "wq": dense(ks[0], (cfg.d_model, h)),
            "wk": dense(ks[1], (cfg.d_model, h)),
            "wv": dense(ks[2], (cfg.d_model, h)),
            "wo": dense(ks[3], (h, cfg.d_model)),
            "w1": dense(ks[4], (cfg.d_model, cfg.d_ff)),
            "w2": dense(ks[5], (cfg.d_ff, cfg.d_model)),
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        })
    return params


def _split_heads(x, cfg):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim)


def _pos_encoding(cfg):
    """Sinusoidal positions, [max_seq, d_model]."""
    pos = jnp.arange(cfg.max_seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(cfg.d_model // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * dim / cfg.d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def prefill(params, cfg: ModelConfig, tokens, lengths):
    """Full-prompt forward pass.

    Args:
      tokens:  [B, S] int32, right-padded to cfg.max_seq.
      lengths: [B] int32 valid prompt lengths.

    Returns:
      logits:  [B, vocab] at each sequence's last valid position.
      k_cache: [L, B, S, H, D] f32.
      v_cache: [L, B, S, H, D] f32.
    """
    b, s = tokens.shape
    x = params["embed"][tokens] + _pos_encoding(cfg)[None, :s]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    pad = jnp.arange(s)[None, :] < lengths[:, None]      # [B, S]
    mask = causal[None, None] & pad[:, None, None, :]    # [B, 1, S, S]

    ks, vs = [], []
    for layer in params["layers"]:
        h = rmsnorm(x, layer["norm1"])
        q = _split_heads(h @ layer["wq"], cfg)
        k = _split_heads(h @ layer["wk"], cfg)
        v = _split_heads(h @ layer["wv"], cfg)
        ks.append(k)
        vs.append(v)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        x = x + attn.reshape(b, s, -1) @ layer["wo"]
        h2 = rmsnorm(x, layer["norm2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]

    x = rmsnorm(x, params["final_norm"])
    # Logits at the last valid position of each sequence.
    idx = jnp.clip(lengths - 1, 0, s - 1)
    last = jnp.take_along_axis(x, idx[:, None, None].repeat(cfg.d_model, -1), 1)
    logits = last[:, 0, :] @ params["unembed"]
    k_cache = jnp.stack(ks)  # [L, B, S, H, D]
    v_cache = jnp.stack(vs)
    return logits, k_cache, v_cache


def decode_step(params, cfg: ModelConfig, tokens, k_cache, v_cache, lengths):
    """One autoregressive decode iteration for the whole batch.

    Args:
      tokens:  [B] int32 last generated token per sequence.
      k_cache: [L, B, S, H, D] f32; positions >= lengths are garbage.
      v_cache: [L, B, S, H, D].
      lengths: [B] int32 tokens already in the cache.

    Returns:
      (logits [B, vocab], k_cache, v_cache) with the new K/V written at
      position `lengths` (caller increments lengths).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens] + _pos_encoding(cfg)[lengths]  # [B, D_model]

    def write_at(cache, new, pos):
        # cache: [B, S, H, D], new: [B, H, D], pos: [B]
        def one(c, n, p):
            return jax.lax.dynamic_update_slice(c, n[None], (p, 0, 0))
        return jax.vmap(one)(cache, new, pos)

    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["norm1"])
        q = (h @ layer["wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(b, cfg.n_heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(b, cfg.n_heads, cfg.head_dim)
        kc = write_at(k_cache[li], k, lengths)
        vc = write_at(v_cache[li], v, lengths)
        new_k.append(kc)
        new_v.append(vc)
        # Layer-1 Pallas kernel on the decode hot path.
        attn = decode_attention(q, kc, vc, lengths + 1)
        x = x + attn.reshape(b, -1) @ layer["wo"]
        h2 = rmsnorm(x, layer["norm2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]

    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def decode_step_ref(params, cfg, tokens, k_cache, v_cache, lengths):
    """Oracle decode step: identical math with ref attention (no Pallas)."""
    from compile.kernels.ref import decode_attention_ref

    b = tokens.shape[0]
    x = params["embed"][tokens] + _pos_encoding(cfg)[lengths]

    def write_at(cache, new, pos):
        def one(c, n, p):
            return jax.lax.dynamic_update_slice(c, n[None], (p, 0, 0))
        return jax.vmap(one)(cache, new, pos)

    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["norm1"])
        q = (h @ layer["wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(b, cfg.n_heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(b, cfg.n_heads, cfg.head_dim)
        kc = write_at(k_cache[li], k, lengths)
        vc = write_at(v_cache[li], v, lengths)
        new_k.append(kc)
        new_v.append(vc)
        attn = decode_attention_ref(q, kc, vc, lengths + 1)
        x = x + attn.reshape(b, -1) @ layer["wo"]
        h2 = rmsnorm(x, layer["norm2"])
        x = x + jax.nn.gelu(h2 @ layer["w1"]) @ layer["w2"]

    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


@functools.lru_cache(maxsize=4)
def bound_model(seed=None):
    """(cfg, params) with weights closed over — the unit aot.py lowers."""
    cfg = ModelConfig() if seed is None else ModelConfig(seed=seed)
    return cfg, init_params(cfg)
