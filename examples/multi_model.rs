//! Multi-model serving (the paper's W_B): fine-tuned model variants
//! multiplexed on a shared fleet, where model swapping and request
//! grouping dominate. Reproduces the §8.2 story: QLM's request groups
//! amortize swaps; EDF thrashes; static vLLM placement strands models.
//!
//!     cargo run --release --example multi_model

use qlm::backend::{ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::coordinator::lso::LsoConfig;
use qlm::sim::{fleet_a100, SimConfig, Simulation};
use qlm::workload::{Trace, WorkloadSpec};

fn main() {
    // W_B: Batch-1 on fine-tuned Mistral-7B + Llama-70B; Batch-2 on
    // fine-tuned Vicuna-13B + Llama-70B (§8, Workloads).
    let spec = WorkloadSpec::w_b(
        vec![ModelId(3), ModelId(4)],
        vec![ModelId(5), ModelId(6)],
        10.0,
        1200,
    );
    let trace = Trace::generate(&spec, 7);
    let catalog = ModelCatalog::paper_multi_model();
    println!(
        "workload: {} requests across {} models\n",
        trace.len(),
        trace.models().len()
    );

    let policies = [
        Policy::qlm(),
        Policy::qlm_with(LsoConfig::without_swapping()),
        Policy::Edf,
        Policy::VllmFcfs,
        Policy::Shepherd,
    ];
    println!(
        "{:<14} {:>8} {:>10} {:>8} {:>9}",
        "policy", "slo%", "req/s", "swaps", "p99 ttft"
    );
    for p in policies {
        let cfg = SimConfig::new(fleet_a100(3), catalog.clone(), p);
        let m = Simulation::new(cfg, &trace).run(&trace);
        println!(
            "{:<14} {:>7.1}% {:>10.2} {:>8} {:>8.1}s",
            m.policy,
            100.0 * m.slo_attainment(),
            m.throughput_rps(),
            m.total_model_swaps(),
            m.ttft_percentile(99.0),
        );
    }
    println!("\nExpected shape (paper Figs. 12-14): QLM highest slo%/req/s with");
    println!("few swaps; EDF swap-thrashes; vLLM strands unpinned models.");
}
