//! End-to-end driver across all three layers: load the AOT-compiled tiny
//! transformer (L2 JAX model + L1 Pallas decode-attention kernel, baked
//! into HLO text) via the PJRT runtime, and serve batched requests from
//! rust (L3) with QLM-style deadline ordering — proving the stack
//! composes with Python nowhere on the request path.
//!
//!     make artifacts && cargo run --release --example e2e_serve
//!
//! Reports TTFT and decode throughput; results are recorded in
//! EXPERIMENTS.md §E2E.

use qlm::runtime::{EngineConfig, EngineRequest, ServeEngine, TinyModel};
use qlm::util::percentile;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = TinyModel::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;
    println!(
        "model: {} params, vocab {}, {} layers, max_seq {} — platform {}",
        model.manifest.param_count,
        model.manifest.vocab,
        model.manifest.n_layers,
        model.manifest.max_seq,
        model.platform()
    );

    let prompts = [
        "Queue management for SLO-oriented large language model serving",
        "Interactive requests have tight latency SLO requirements",
        "Batch requests tolerate minutes to hours of queueing delay",
        "The RWT estimator bounds waiting time via the CLT",
        "Request eviction prevents head-of-line blocking",
        "Model swapping costs dominate multi-model serving",
        "Virtual queues order request groups per instance",
        "Continuous batching keeps the GPU memory saturated",
        "PagedAttention manages the KV cache like virtual memory",
        "The global scheduler solves a linear program",
        "Load balancing assigns groups to the least-loaded queue",
        "Earliest deadline first thrashes across models",
    ];

    // Mixed SLOs: every third request is interactive.
    let mut engine = ServeEngine::new(model, EngineConfig::default());
    for (i, p) in prompts.iter().enumerate() {
        engine.submit(EngineRequest {
            id: i as u64,
            prompt: p.as_bytes().to_vec(),
            max_new_tokens: 24,
            slo_s: if i % 3 == 0 { 0.5 } else { 30.0 },
        });
    }

    let t0 = std::time::Instant::now();
    let results = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    let ttfts: Vec<f64> = results.iter().map(|r| r.ttft_s).collect();
    let tokens: usize = results.iter().map(|r| r.output.len()).sum();
    println!(
        "\nserved {} requests / {} tokens in {:.2}s",
        results.len(),
        tokens,
        wall
    );
    println!(
        "throughput: {:.1} req/s, {:.0} tok/s decode ({} batches)",
        results.len() as f64 / wall,
        engine.stats.decode_tokens_per_s(),
        engine.stats.batches
    );
    println!(
        "TTFT: p50 {:.3}s  p99 {:.3}s  (prefill total {:.2}s, decode total {:.2}s)",
        percentile(&ttfts, 50.0),
        percentile(&ttfts, 99.0),
        engine.stats.prefill_s,
        engine.stats.decode_s
    );
    // Show one generation to prove real tokens flow end to end.
    let r0 = &results[0];
    println!(
        "\nrequest {} generated {} tokens: {:?}...",
        r0.id,
        r0.output.len(),
        &r0.output[..r0.output.len().min(10)]
    );
    Ok(())
}
