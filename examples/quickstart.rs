//! Quickstart: serve the paper's single-model mixed workload (W_A) on a
//! small simulated A100 fleet with QLM and print the headline metrics —
//! the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use qlm::backend::{ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::sim::{fleet_a100, SimConfig, Simulation};
use qlm::workload::{SloClass, Trace, WorkloadSpec};

fn main() {
    // 1. A workload: interactive (20 s TTFT SLO) + batch requests for
    //    Vicuna-13B, Poisson arrivals at 20 req/s — the paper's W_A shape.
    let spec = WorkloadSpec::w_a(ModelId(1), 20.0, 1500);
    let trace = Trace::generate(&spec, 42);
    println!(
        "workload: {} requests, mean output {:.0} tokens",
        trace.len(),
        trace.mean_output_tokens()
    );

    // 2. A cluster: four simulated A100 serving instances.
    let fleet = fleet_a100(4);

    // 3. QLM: request groups + RWT estimator + global scheduler + LSOs.
    let cfg = SimConfig::new(fleet, ModelCatalog::paper(), Policy::qlm());
    let metrics = Simulation::new(cfg, &trace).run(&trace);

    println!("{}", metrics.summary());
    for class in [SloClass::Interactive, SloClass::Batch1, SloClass::Batch2] {
        println!(
            "  {:12} SLO attainment: {:5.1}%",
            class.name(),
            100.0 * metrics.slo_attainment_class(class)
        );
    }
    println!(
        "  p50 TTFT {:.2}s  p99 TTFT {:.2}s  device util {:.0}%",
        metrics.ttft_percentile(50.0),
        metrics.ttft_percentile(99.0),
        100.0 * metrics.mean_utilization()
    );

    // 4. Compare against vanilla vLLM FCFS on the identical workload.
    let cfg = SimConfig::new(fleet_a100(4), ModelCatalog::paper(), Policy::VllmFcfs);
    let baseline = Simulation::new(cfg, &trace).run(&trace);
    println!("{}", baseline.summary());
    println!(
        "QLM vs vLLM interactive SLO attainment: {:.1}% vs {:.1}%",
        100.0 * metrics.slo_attainment_class(SloClass::Interactive),
        100.0 * baseline.slo_attainment_class(SloClass::Interactive),
    );
}
