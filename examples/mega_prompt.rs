//! Mega-prompt workload (the paper's W_C, §8.3 / Fig. 16): a fraction of
//! requests carry 3K-4K-token prompts whose KV cache monopolizes GPU
//! memory and head-of-line-blocks regular requests. QLM's RWT estimator
//! sees the distinct token distribution and isolates mega prompts onto
//! instances of their own.
//!
//!     cargo run --release --example mega_prompt

use qlm::backend::{ModelCatalog, ModelId};
use qlm::baselines::Policy;
use qlm::sim::{fleet_mixed, SimConfig, Simulation};
use qlm::workload::{Trace, WorkloadSpec};

fn main() {
    // Memory-scarce setting: Mistral-7B on A10s (the regime where mega
    // prompts genuinely contend for KV space).
    let catalog = ModelCatalog::paper();
    println!(
        "{:<12} {:>10} {:>10} {:>12}",
        "mega_frac", "qlm_slo%", "vllm_slo%", "qlm_p99_ttft"
    );
    for mega_frac in [0.0, 0.05, 0.15, 0.4] {
        let spec = WorkloadSpec::w_c(vec![ModelId(0)], vec![ModelId(0)], 15.0, 1000, mega_frac);
        let trace = Trace::generate(&spec, 16);
        let qlm = Simulation::new(
            SimConfig::new(fleet_mixed(3, 1.0), catalog.clone(), Policy::qlm()),
            &trace,
        )
        .run(&trace);
        let vllm = Simulation::new(
            SimConfig::new(fleet_mixed(3, 1.0), catalog.clone(), Policy::VllmFcfs),
            &trace,
        )
        .run(&trace);
        println!(
            "{:<12.2} {:>9.1}% {:>9.1}% {:>11.1}s",
            mega_frac,
            100.0 * qlm.slo_attainment(),
            100.0 * vllm.slo_attainment(),
            qlm.ttft_percentile(99.0),
        );
    }
    println!("\nExpected shape (paper Fig. 16): QLM's edge is largest at small");
    println!("mega fractions (it can isolate them); benefit shrinks as they dominate.");
}
